"""Batched max-plus evaluation of LogGPS scenario grids (jit + vmap).

This module owns the jitted cores and the populated-axis forward cache
(:func:`_get_forward`): graph [G], candidate-cost [K] and scenario [S]
batch axes compose freely via vmap (and any one of them can shard across
devices).  The user-facing evaluator is :class:`repro.sweep.api.Engine`;
the :class:`SweepEngine` / :class:`MultiSweepEngine` classes below are
deprecation-warned shims over it, kept bit-identical for legacy callers.

One call evaluates a whole :class:`~repro.sweep.scenarios.ScenarioBatch`
against a :class:`~repro.sweep.compile.CompiledPlan`:

    T[s]        makespan per scenario
    λ[s, c]     ∂T/∂L_c — messages of class c on the critical path, recovered
                by the same argmax critical-path backtrace (with the scalar
                engine's max-slope tie-break) so results match
                ``core.dag.LevelPlan.forward`` to float64 round-off, and the
                HiGHS lower-bound marginals of the explicit LP
    ρ[s, c]     latency share L_c·λ_c / T

Backends
--------
``segment`` (default): pure-``jnp`` per-level relaxation over the compiled
per-vertex in-edge tensors — gather, max-reduce, ``dynamic_update_slice``;
no scatters, which is what makes it fast on CPU and TPU alike.  Runs in
float64 inside a scoped ``enable_x64`` so the sweep is bit-compatible with
the numpy engine.  The per-scenario axis is ``vmap``'d.

``pallas``: the ``repro.kernels.maxplus`` TPU kernel as the inner scatter —
each level's scatter-max is a (max,+) mat-vec of a 0/−inf incidence matrix
with per-edge candidate values, scenarios riding the 128-wide lane axis.
λ/ρ requests run the argmax-emitting kernel variant (per-level realizing
edge slots recorded forward, consumed by a reverse backtrace scan), so the
pallas backend serves T *and* sensitivities natively — no segment
redispatch.  Float32 accumulators (like the TPU VPU): tolerance ≈ 1e-6
relative vs segment.

``sparse``: compact CSR-style slot lists (``compile.SparsePlan``) — each
level is a fixed-size window of the level-sorted edge list relaxed with a
``segment_max`` over window-local destinations, so memory is O(nv + ne)
with no dense padding at all.  Float64 with the same tie-break op
sequences as ``segment`` (T and λ bit-identical); the scenario axis is
``vmap``'d and is the only batch axis.  :class:`repro.sweep.api.Engine`
auto-switches to it when a graph's dense envelope would blow past
``MAX_DENSE_BYTES``.

λ on the segment backend is **two-pass** by default: a values-only
``fori_loop`` forward recording per-level argmax slots, then a reverse
backtrace scan — bit-identical to the original fused single-loop backtrace
(kept under ``fused=True`` as the reference) at roughly the values-only
program's compile cost.

Device sharding: ``run(..., shard=...)`` splits the scenario axis
(:class:`SweepEngine`) or the MultiPlan's leading graph axis
(:class:`MultiSweepEngine`) across local devices with ``shard_map``;
per-element arithmetic is unchanged, so sharded results are bit-identical
to single-device runs.

Candidate costs: ``run(..., costs=CostBatch)`` adds a third batch axis —
K patched cost blocks (same plan structure, new per-edge constants) vmap
alongside the scenario axis on either backend, λ/ρ included.  Structure
tensors stay unbatched inside the vmap, so every cost block of every call
reuses the ONE compiled program of the plan's shape bucket: the zero-
recompile path behind ``core.placement``'s greedy search.

Structure blocks: ``Query(structure=StructureBatch)`` adds a B variant
axis over the *structure* tensors instead — rewired slot sources and edge
masks batched, everything untouched broadcast — so a whole topology study
(edge re-wirings, or separately-compiled plans stamped onto a union
envelope) runs as ONE compiled program per super-envelope, λ tie-breaks
re-derived per variant in-kernel.

Also here: lockstep-batched versions of the bisection loops from
``core.dag`` (``tolerance_batched``, ``breakpoints_batched``) — every probe
round becomes ONE engine call over all active intervals.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.loggps import LogGPS

from .cache import DEFAULT_CACHE, SweepCache
from .compile import (CompiledPlan, CostBatch, MultiPlan,  # noqa: F401
                      _bucket, compile_plan)
from .scenarios import ScenarioBatch, latency_grid

BIG = 1e30          # matches kernels.maxplus NEG_INF magnitude
ATOL = 1e-12        # the scalar engine's tie tolerances (dag.LevelPlan)


@dataclasses.dataclass
class SweepResult:
    T: np.ndarray                    # [S] µs
    lam: Optional[np.ndarray]        # [S, nclass] or None (values-only run)
    rho: Optional[np.ndarray]        # [S, nclass] or None
    scenarios: ScenarioBatch
    backend: str
    from_cache: bool = False

    @property
    def S(self) -> int:
        return int(self.T.shape[0])

    def argbest(self) -> int:
        """Scenario index with the smallest makespan."""
        return int(np.argmin(self.T))


@dataclasses.dataclass
class CostSweepResult:
    """Per-candidate sweep tensors: row k is cost block k of the
    :class:`~repro.sweep.compile.CostBatch` the run patched in."""

    T: np.ndarray                    # [K, S] µs
    lam: Optional[np.ndarray]        # [K, S, nclass] or None
    rho: Optional[np.ndarray]        # [K, S, nclass] or None
    scenarios: ScenarioBatch
    backend: str
    from_cache: bool = False

    @property
    def K(self) -> int:
        return int(self.T.shape[0])

    @property
    def S(self) -> int:
        return int(self.T.shape[1])

    def __getitem__(self, k: int) -> SweepResult:
        """Candidate k's slice as a plain :class:`SweepResult`."""
        k = int(k)
        return SweepResult(
            T=self.T[k].copy(),
            lam=None if self.lam is None else self.lam[k].copy(),
            rho=None if self.rho is None else self.rho[k].copy(),
            scenarios=self.scenarios, backend=self.backend,
            from_cache=self.from_cache)

    def argbest(self, reduce: str = "mean") -> int:
        """Candidate index minimizing the makespan objective over the grid."""
        if reduce == "mean":
            obj = self.T.mean(axis=1)
        elif reduce == "max":
            obj = self.T.max(axis=1)
        elif reduce == "final":
            obj = self.T[:, -1]
        else:
            raise ValueError(f"unknown reduce {reduce!r}")
        return int(np.argmin(obj))


# -- jitted forwards (module level: the jit cache is shared across engines,
#    and CompiledPlan's bucketed shapes make distinct graphs reuse programs) --

def _jax():
    import jax  # deferred: repro.core must import without jax present
    return jax


_WARNED: set = set()


def _warn_once(key: tuple, message: str, registry: Optional[set] = None) -> None:
    """Emit a RuntimeWarning once per key (backend overrides, engine
    fallbacks) — loud enough to see, quiet enough for sweep loops.

    ``registry`` scopes the once-ness: engines pass their own set so a
    backend override warns once per engine *instance* (a fresh engine in a
    new study warns again) rather than once per process.
    """
    reg = _WARNED if registry is None else registry
    if key not in reg:
        reg.add(key)
        import warnings
        warnings.warn(message, RuntimeWarning, stacklevel=3)


def _make_segment_one(want_lam: bool, fused: bool = False):
    """The single-(graph, scenario) gather/max forward.

    Vertices live at level-major flat slots, each owning a padded row of
    in-edges, so one level is a gather → max over the in-edge axis →
    ``dynamic_update_slice`` of the level's slot block — scatter-free, which
    is what makes the sweep fast on CPU/TPU alike.  ``vmap``'d over the
    scenario axis (and, for :class:`MultiSweepEngine`, the graph axis:
    padding only adds masked −∞ candidates and max is exact, so a packed
    graph's outputs are bit-identical to its solo run).

    λ layouts (``want_lam``): the default is **two-pass** — a values
    forward that records, per slot, the chosen in-edge's source slot
    (critical-path next pointer) and latency row under the scalar engine's
    value/slope/ordinal tie-breaks, then a reverse backtrace *pointer
    chase* from the sink, then an ascending-level accumulation of the
    visited rows.  The ascending final sum replays the fused layout's
    exact float addition order, so results are *bit-identical* to
    ``fused=True`` — the original single-loop backtrace that drags a
    [nflat, nclass] slope accumulation through every level (kept as the
    equivalence reference).  The recorded rows are plain per-level writes,
    so the two-pass loop body stays close to the values-only body; on
    XLA:CPU the two layouts measure within ±15% of each other on compile
    and runtime, because the tie-break arithmetic itself (not the slope
    carry) is what keeps any bit-exact λ program well above the
    values-only compile cost — see ``benchmarks/bench_sweep.py``'s
    ``lam_compile`` lines.
    """
    jax = _jax()
    jnp = jax.numpy
    dus = jax.lax.dynamic_update_slice

    def one(vsrc, vmaskd, vconst, vgap, vgclass, vlat, vlat_sum, vcost_lv,
            valid_flat, vert_of_slot, Lrow, gsrow, *link):
        nlv, Vmax, Dmax = vsrc.shape
        nc = vlat.shape[3]
        nflat = valid_flat.shape[0]          # nlv·Vmax + 1 (dummy tail)
        didx = jnp.arange(Dmax, dtype=jnp.int32)

        def relax(lv, t_end):
            """[Vmax, Dmax] candidate ends and [Vmax] level start times."""
            gse = gsrow[vgclass[lv]]
            if link:
                # congestion closure: per-link effective-gap inflation.
                # lscale ≡ 1.0 multiplies exactly, so a zero-congestion
                # fixed point is bit-identical to the plain forward.
                vlink, lscale = link
                gse = gse * lscale[vlink[lv]]
            w = (vconst[lv] + vgap[lv] * (gse - 1.0)
                 + vlat[lv] @ Lrow)
            cand = jnp.where(vmaskd[lv], t_end[vsrc[lv]] + w, -BIG)
            ts = jnp.maximum(jnp.max(cand, axis=1), 0.0)   # t_start ≥ 0
            return cand, ts

        def choose(lv, t_end, ssum):
            """Per-vertex chosen in-edge ordinal for one level.

            The scalar LevelPlan.forward rule: realizing edges (value within
            ATOL of the level max), max-total-slope tie-break, then max
            ordinal.  Shared by the fused and two-pass layouts so their
            tie-break arithmetic is literally the same ops.
            """
            cand, ts = relax(lv, t_end)
            hit = vmaskd[lv] & (cand >= ts[:, None] - ATOL)
            cs = ssum[vsrc[lv]] + vlat_sum[lv]
            best = jnp.max(jnp.where(hit, cs, -BIG), axis=1)
            sel = hit & (cs >= best[:, None] - ATOL)
            chosen = jnp.max(jnp.where(sel, didx, -1), axis=1)   # [Vmax]
            return ts, chosen, sel

        def sink_slot(t_end, ssum):
            """The scalar rule: among makespan sinks, the max-ssum one with
            the smallest original vertex id."""
            T = jnp.max(jnp.where(valid_flat, t_end, -BIG))
            sink = valid_flat & (t_end >= T - ATOL)
            mx = jnp.max(jnp.where(sink, ssum, -BIG))
            top = sink & (ssum >= mx)
            v = jnp.argmin(jnp.where(top, vert_of_slot,
                                     jnp.iinfo(jnp.int32).max))
            return T, v

        if want_lam and not fused:
            # -- pass 1: values + slope-sum carry, recording per slot the
            #    chosen in-edge's *source slot* (a critical-path next
            #    pointer) and its latency row — no [nflat, nc] slope
            #    accumulation in the loop.  The per-edge reads are ordinal
            #    gathers of exactly the elements the fused layout's one-hot
            #    reductions sum, so every recorded value is bit-identical --
            def body(lv, carry):
                t_end, ssum, nxt, lrow = carry
                ts, chosen, _ = choose(lv, t_end, ssum)
                has = chosen >= 0
                ch = jnp.where(has, chosen, 0)[:, None]
                srcslot = jnp.take_along_axis(vsrc[lv], ch, axis=1)[:, 0]
                vls = jnp.take_along_axis(vlat_sum[lv], ch, axis=1)[:, 0]
                ss_new = jnp.where(has, ssum[srcslot] + vls, 0.0)
                off = lv * Vmax
                own = off + jnp.arange(Vmax, dtype=jnp.int32)
                nxt_row = jnp.where(has, srcslot.astype(jnp.int32), own)
                row = jnp.where(
                    has[:, None],
                    jnp.take_along_axis(vlat[lv], ch[:, :, None],
                                        axis=1)[:, 0], 0.0)
                return (dus(t_end, ts + vcost_lv[lv], (off,)),
                        dus(ssum, ss_new, (off,)),
                        dus(nxt, nxt_row, (off,)),
                        dus(lrow, row, (off, 0)))

            init = (jnp.zeros(nflat), jnp.zeros(nflat),
                    jnp.arange(nflat, dtype=jnp.int32),
                    jnp.zeros((nflat, nc)))
            t_end, ssum, nxt, lrow = jax.lax.fori_loop(0, nlv, body, init)
            T, v = sink_slot(t_end, ssum)

            # -- pass 2: reverse backtrace = pointer chase from the sink
            #    slot (slots without a chosen edge self-point, with zero
            #    latency rows, so stalled steps are exact no-ops) ------------
            _, visited = jax.lax.scan(lambda cur, _: (nxt[cur], cur),
                                      jnp.int32(v), None, length=nlv)
            # -- pass 3: ascending-level accumulation — flipping the walk
            #    replays the fused layout's exact float addition order
            #    (leading stall levels add exact +0.0), so λ is
            #    bit-identical to ``fused=True`` ----------------------------
            lam, _ = jax.lax.scan(lambda acc, r: (acc + r, 0.0),
                                  jnp.zeros(nc), lrow[visited][::-1])
            return T, lam

        if want_lam:
            # -- fused reference layout: [nflat, nc] slope carry in-loop,
            #    one-hot masked reductions (the original formulation) --------
            def body(lv, carry):
                t_end, slope, ssum = carry
                ts, chosen, sel = choose(lv, t_end, ssum)
                onehot = sel & (didx[None, :] == chosen[:, None])
                srcv = jnp.max(jnp.where(onehot, vsrc[lv], 0), axis=1)
                has = chosen >= 0
                sl_new = jnp.where(
                    has[:, None], slope[srcv]
                    + jnp.sum(jnp.where(onehot[:, :, None], vlat[lv], 0.0),
                              axis=1), 0.0)
                ss_new = jnp.where(
                    has, ssum[srcv]
                    + jnp.sum(jnp.where(onehot, vlat_sum[lv], 0.0), axis=1),
                    0.0)
                off = lv * Vmax
                return (dus(t_end, ts + vcost_lv[lv], (off,)),
                        dus(slope, sl_new, (off, 0)),
                        dus(ssum, ss_new, (off,)))

            init = (jnp.zeros(nflat), jnp.zeros((nflat, nc)), jnp.zeros(nflat))
            t_end, slope, ssum = jax.lax.fori_loop(0, nlv, body, init)
            T, v = sink_slot(t_end, ssum)
            return T, slope[v]

        def body(lv, t_end):
            _, ts = relax(lv, t_end)
            return dus(t_end, ts + vcost_lv[lv], (lv * Vmax,))

        t_end = jax.lax.fori_loop(0, nlv, body, jnp.zeros(nflat))
        T = jnp.max(jnp.where(valid_flat, t_end, -BIG))
        return T, jnp.zeros((vlat.shape[3],))

    return one


def _segment_core_axes(want_lam: bool, multi: bool, costs: Optional[tuple],
                       fused: bool = False,
                       structure: Optional[tuple] = None):
    """The generalized segment forward: one vmap per populated batch axis.

    The innermost vmap always rides scenarios [S]; ``costs`` (a
    per-``_SEG_COST_FIELDS`` vmap-axis tuple, 0 = patched/batched, None =
    shared) adds the candidate axis [K] over ONLY the patched cost
    tensors; ``multi`` adds the MultiPlan graph axis [G] over every input
    (cost tensors then carry [G, K, ...] when both axes are populated).
    Composition order fixes the canonical output layout [G?, K?, S] — and
    because each added vmap leaves the per-element arithmetic untouched,
    every populated-axis combination is bit-identical to the equivalent
    solo/rebuild runs (the conformance matrix's contract).
    """
    jax = _jax()
    one = _make_segment_one(want_lam, fused)
    core = jax.vmap(one, in_axes=(None,) * 10 + (0, 0))          # S
    if costs is not None:
        core = jax.vmap(core, in_axes=(None, None) + tuple(costs)
                        + (None,) * 3 + (None, None))            # K
    if structure is not None:
        core = jax.vmap(core, in_axes=tuple(structure))          # B
    if multi:
        core = jax.vmap(core, in_axes=(0,) * 12)                 # G
    return core


def _segment_core(want_lam: bool, fused: bool = False):
    """Unjitted forward over one graph × S scenarios → T [S], λ [S, nc]."""
    return _segment_core_axes(want_lam, False, None, fused)


def _segment_core_multi(want_lam: bool, fused: bool = False):
    """Unjitted forward over G graphs × S scenarios → T [G, S], λ [G, S, nc].

    Inner vmap rides scenarios, outer vmap rides the MultiPlan's graph axis
    (every plan tensor gains a leading G dim, and scenarios are per-graph
    [G, S, ·] so variant groups with different base points batch together).
    """
    return _segment_core_axes(want_lam, True, None, fused)


def _congestion_core_axes(want_lam: bool, costs: Optional[tuple] = None):
    """Congestion-aware segment forward: an iterated fixed point per lane.

    The LogGPS gap term models an uncongested link; when many messages
    share one physical link (``CompiledPlan.vlink``), their gap shares
    contend.  We close the loop with a standard utilization model: evaluate
    the forward, aggregate each link's *offered* gap-time ``busy_l``
    (scatter-add of ``vgap · gscale`` over link ids — a constant of the
    scenario, computed once), read utilization ``u_l = busy_l / T``, and
    inflate each link's effective gap by ``1 + α_c·max(u_l − β_c, 0)``
    (α, β per network class) before re-evaluating.  Iteration runs as a
    ``lax.while_loop`` *inside* the jitted program with 0.5 damping and a
    runtime (max_iters, tol) stopping rule — no recompile across knob
    values, and under vmap all S scenarios (and K cost blocks) advance in
    lockstep with converged lanes frozen (their lscale no longer updates;
    per-lane iteration counts are reported).

    With α ≡ 0 the update is the identity (lscale stays exactly 1.0) and
    the loop exits after one iteration — the final evaluation multiplies
    every gap by exactly 1.0, so a zero-congestion run is bit-identical
    to the plain segment backend (the conformance contract).

    λ comes from one final λ-recording evaluation at the converged lscale:
    the fixed point's sensitivities are read at its solution (the inner
    loop stays values-only, which keeps the program small).
    """
    jax = _jax()
    jnp = jax.numpy
    one_vals = _make_segment_one(False)
    one_fin = _make_segment_one(want_lam)

    def fixed_point(vsrc, vmaskd, vconst, vgap, vgclass, vlat, vlat_sum,
                    vcost_lv, valid_flat, vert_of_slot, vlink, link_cls,
                    link_mask, alpha, beta, max_iters, tol, Lrow, gsrow):
        Lp = link_mask.shape[0]
        # offered gap-time per physical link (pad/dep slots carry vgap = 0
        # and land in the dummy bin, which link_mask zeroes out below)
        busy = jax.ops.segment_sum((vgap * gsrow[vgclass]).ravel(),
                                   vlink.ravel(), num_segments=Lp)
        a_l = jnp.where(link_mask, alpha[link_cls], 0.0)
        b_l = beta[link_cls]

        def cond(c):
            _, it, done = c
            return (it < max_iters) & ~done

        def body(c):
            ls, it, done = c
            T, _ = one_vals(vsrc, vmaskd, vconst, vgap, vgclass, vlat,
                            vlat_sum, vcost_lv, valid_flat, vert_of_slot,
                            Lrow, gsrow, vlink, ls)
            util = busy / jnp.maximum(T, 1e-30)
            tgt = 1.0 + a_l * jnp.maximum(util - b_l, 0.0)
            new = ls + 0.5 * (tgt - ls)          # damped update
            fin = jnp.max(jnp.abs(new - ls)) <= tol
            return (jnp.where(done, ls, new), it + jnp.where(done, 0, 1),
                    done | fin)

        ls, iters, _ = jax.lax.while_loop(
            cond, body, (jnp.ones(Lp), jnp.int32(0), jnp.bool_(False)))
        T, lam = one_fin(vsrc, vmaskd, vconst, vgap, vgclass, vlat,
                         vlat_sum, vcost_lv, valid_flat, vert_of_slot,
                         Lrow, gsrow, vlink, ls)
        return T, lam, iters

    core = jax.vmap(fixed_point, in_axes=(None,) * 17 + (0, 0))     # S
    if costs is not None:
        core = jax.vmap(core, in_axes=(None, None) + tuple(costs)
                        + (None,) * 10 + (None, None))              # K
    return core


#: cost tensors each backend's forward consumes, in positional order
#: (subset of ``compile.COST_FIELDS``; the rest of the 10 plan args is
#: immutable structure).  The dicts map field name → position in the
#: backend's 10 staged plan args (``_stage_arrays`` order).
_SEG_COST_FIELDS = ("vconst", "vgap", "vgclass", "vlat", "vlat_sum")
_PAL_COST_FIELDS = ("econst", "egap", "egclass", "elat")
_SEG_COST_POS = {n: i for i, n in enumerate(_SEG_COST_FIELDS, start=2)}
_PAL_COST_POS = {n: i for i, n in enumerate(_PAL_COST_FIELDS, start=3)}

#: structure-batch tensors each backend stages, mapped to their position in
#: the 10 staged plan args.  The pallas 0/−inf indicator (position 0) is
#: derived from emask/edstl and handled separately by the engine; ``edstl``
#: itself is consumed only through the indicator.
_SEG_STRUCT_POS = {"vsrc": 0, "vmaskd": 1, "vconst": 2, "vgap": 3,
                   "vgclass": 4, "vlat": 5, "vlat_sum": 6, "vcost_lv": 7,
                   "valid_flat": 8, "vert_of_slot": 9}
_PAL_STRUCT_POS = {"esrc": 1, "emask": 2, "econst": 3, "egap": 4,
                   "egclass": 5, "elat": 6, "vcost_lv": 7,
                   "valid_flat": 8, "vert_of_slot": 9}
#: structure tensors that determine one backend's results — the view the
#: engine hashes a StructureBatch under when keying cached results
_SEG_STRUCT_FIELDS = tuple(_SEG_STRUCT_POS)
_PAL_STRUCT_FIELDS = ("esrc", "edstl", "emask", "econst", "egap",
                      "egclass", "elat", "vcost_lv", "valid_flat",
                      "vert_of_slot")


def _same_buffer(a: np.ndarray, b: np.ndarray) -> bool:
    """True iff two arrays are literally the same memory view (start,
    layout, dtype) — the test that lets a cost-batched run reuse the
    engine's staged device copy of an unpatched cost tensor.  Strides on
    size-≤1 axes are ignored: they address no memory, and broadcast views
    report 0 there where the base array reports its natural stride."""
    def eff(x):
        return tuple(s for s, n in zip(x.strides, x.shape) if n > 1)

    return (a.shape == b.shape and a.dtype == b.dtype
            and eff(a) == eff(b)
            and a.__array_interface__["data"][0]
            == b.__array_interface__["data"][0])


def _segment_core_costs(want_lam: bool, axes: tuple, fused: bool = False):
    """Forward over K cost blocks × S scenarios → T [K, S], λ [K, S, nc].

    The candidate axis vmaps ONLY the patched cost tensors (``axes``: one
    entry per ``_SEG_COST_FIELDS`` member, 0 = batched, None = shared);
    structure and unpatched costs ride along unbatched.  The per-element
    arithmetic is the single-(graph, scenario) ``one`` unchanged, so row k
    is bit-identical to a solo run of a plan rebuilt with cost block k
    (the placement loop's exactness guarantee)."""
    return _segment_core_axes(want_lam, False, axes, fused)


def _dense_core_axes(want_lam: bool, multi: bool, costs: Optional[tuple],
                     structure: Optional[tuple] = None):
    """The generalized pallas forward.  The scenario axis rides the
    kernel's 128-wide lanes and the graph axis (``multi``) rides the
    batched kernel's outer grid axis, so neither is a vmap; ``costs`` adds
    the candidate axis by vmapping ONLY the patched cost tensors over the
    (graph-batched) kernel core — output layout [K?, G?, S], which the
    engine transposes to the canonical [G?, K?, S].  ``structure`` adds
    the B variant axis outermost (per-staged-arg vmap axes, indicator
    included) — output layout [B, K?, S]."""
    jax = _jax()
    core = (_dense_core_multi if multi else _dense_core)(want_lam)
    if costs is not None:
        core = jax.vmap(core, in_axes=(None,) * 3 + tuple(costs)
                        + (None,) * 3 + (None, None))
    if structure is not None:
        core = jax.vmap(core, in_axes=tuple(structure))           # B
    return core


def _dense_core_costs(want_lam: bool, axes: tuple):
    """Pallas forward over K cost blocks × S scenarios: the (max,+) kernel
    is vmapped on the candidate axis (the 0/−inf indicator is structure and
    stays unbatched); λ via the argmax kernel exactly as in solo runs.
    ``axes``: per-``_PAL_COST_FIELDS`` vmap axis (0 or None)."""
    return _dense_core_axes(want_lam, False, axes)


def _dense_core(want_lam: bool = False):
    """Forward with the Pallas (max,+) kernel as the inner scatter.

    Values-only runs the plain kernel; with ``want_lam`` the argmax-emitting
    kernel variant records each level's realizing edge slot (tie keys =
    cumulative slope sums, mirroring the segment rule) and a reverse
    backtrace scan over the recorded slots recovers λ — T/λ/ρ straight from
    the pallas backend, no segment redispatch.  Float32 accumulators (TPU
    VPU layout) → T matches segment to ~1e-6 relative.  Tie caveat: the
    kernel compares candidates *exactly* (a tolerance-grouped tie set is
    not associative across its blocked reduction), where segment groups
    float64 candidates within ATOL — structurally tied paths still compare
    equal in f32 (identical op sequences), but a pair of paths whose f64
    sums tie only to within ATOL can resolve differently and shift λ by a
    whole count; segment is the bit-exact reference when that matters.
    """
    jax = _jax()
    jnp = jax.numpy
    from repro.kernels.maxplus.ops import (maxplus_matvec,
                                           maxplus_matvec_argmax)

    def fwd(A, esrc, emask, econst, egap, egclass, elat, vcost_lv,
            valid_flat, vert_of_slot, Lmat, GSmat):
        nlv, Emax = esrc.shape
        Vmax = vcost_lv.shape[1]
        S = Lmat.shape[0]
        nc = elat.shape[2]
        nflat = valid_flat.shape[0]
        elat_sum = jnp.sum(elat, axis=2)                        # [nlv, Emax]

        def edge_cand(lv, t_end):
            gse = GSmat[:, egclass[lv]].T                       # [Emax, S]
            w = (econst[lv][:, None] + egap[lv][:, None] * (gse - 1.0)
                 + elat[lv] @ Lmat.T)
            cand = t_end[esrc[lv]] + w
            return jnp.where(emask[lv][:, None], cand,
                             -BIG).astype(jnp.float32)

        if not want_lam:
            def body(lv, t_end):
                ts = maxplus_matvec(A[lv], edge_cand(lv, t_end))
                ts = jnp.maximum(ts, 0.0)                       # [Vmax, S]
                return jax.lax.dynamic_update_slice(
                    t_end, ts + vcost_lv[lv][:, None], (lv * Vmax, 0))

            t_end = jax.lax.fori_loop(0, nlv, body,
                                      jnp.zeros((nflat, S), jnp.float32))
            T = jnp.max(jnp.where(valid_flat[:, None], t_end, -BIG), axis=0)
            return T, jnp.zeros((S, nc), jnp.float32)

        def body(lv, carry):
            t_end, ssum, chosen_all = carry
            cand = edge_cand(lv, t_end)
            cs = (ssum[esrc[lv]]
                  + elat_sum[lv][:, None]).astype(jnp.float32)  # [Emax, S]
            raw, eidx = maxplus_matvec_argmax(A[lv], cand, cs)  # [Vmax, S]
            ts = jnp.maximum(raw, 0.0)
            chosen = jnp.where(raw >= 0.0, eidx, -1)
            e_s = jnp.where(chosen >= 0, chosen, 0)
            src_slot = esrc[lv][e_s]                            # [Vmax, S]
            gss = jnp.take_along_axis(ssum, src_slot, axis=0)
            ss_new = jnp.where(chosen >= 0, gss + elat_sum[lv][e_s], 0.0)
            off = lv * Vmax
            return (jax.lax.dynamic_update_slice(
                        t_end, ts + vcost_lv[lv][:, None], (off, 0)),
                    jax.lax.dynamic_update_slice(ssum, ss_new, (off, 0)),
                    jax.lax.dynamic_update_slice(chosen_all, chosen[None],
                                                 (lv, 0, 0)))

        init = (jnp.zeros((nflat, S), jnp.float32),
                jnp.zeros((nflat, S), jnp.float32),
                jnp.full((nlv, Vmax, S), -1, jnp.int32))
        t_end, ssum, chosen_all = jax.lax.fori_loop(0, nlv, body, init)
        T = jnp.max(jnp.where(valid_flat[:, None], t_end, -BIG), axis=0)
        sink = valid_flat[:, None] & (t_end >= T[None, :])
        mx = jnp.max(jnp.where(sink, ssum, -BIG), axis=0)
        top = sink & (ssum >= mx[None, :])
        vsel = jnp.argmin(jnp.where(top, vert_of_slot[:, None],
                                    jnp.iinfo(jnp.int32).max), axis=0)

        sidx = jnp.arange(S)

        def back(i, carry):
            cur, lam = carry
            lv = nlv - 1 - i
            onlvl = (cur >= lv * Vmax) & (cur < (lv + 1) * Vmax)
            off = jnp.where(onlvl, cur - lv * Vmax, 0)
            e = chosen_all[lv, off, sidx]                       # [S]
            take = onlvl & (e >= 0)
            e_s = jnp.where(take, e, 0)
            lam = lam + jnp.where(take[:, None], elat[lv, e_s, :], 0.0)
            cur = jnp.where(take, esrc[lv, e_s], cur)
            return cur, lam

        _, lam = jax.lax.fori_loop(
            0, nlv, back,
            (vsel.astype(jnp.int32), jnp.zeros((S, nc), jnp.float32)))
        return T, lam

    return fwd


def _dense_core_multi(want_lam: bool = False):
    """Multi-graph pallas forward: the batched (max,+) kernel runs every
    packed graph's level scatter in one launch (graphs on the kernel's
    outer grid axis, scenarios on the 128-wide lane axis); with
    ``want_lam`` the batched argmax kernel records the realizing edge slots
    and the reverse backtrace runs per (graph, scenario)."""
    jax = _jax()
    jnp = jax.numpy
    from repro.kernels.maxplus.ops import (maxplus_matvec_argmax_batched,
                                           maxplus_matvec_batched)

    def fwd(A, esrc, emask, econst, egap, egclass, elat, vcost_lv,
            valid_flat, vert_of_slot, Lmat, GSmat):
        G, nlv, Emax = esrc.shape
        Vmax = vcost_lv.shape[2]
        S = Lmat.shape[1]
        nc = elat.shape[3]
        nflat = valid_flat.shape[1]
        elat_sum = jnp.sum(elat, axis=3)                     # [G, nlv, Emax]

        def edge_cand(lv, t_end):
            # gse[g, e, s] = GSmat[g, s, egclass[g, lv, e]]
            gse = jnp.take_along_axis(
                jnp.swapaxes(GSmat, 1, 2), egclass[:, lv][:, :, None], axis=1)
            w = (econst[:, lv][:, :, None]
                 + egap[:, lv][:, :, None] * (gse - 1.0)
                 + jnp.einsum("gec,gsc->ges", elat[:, lv], Lmat))
            cand = jnp.take_along_axis(t_end, esrc[:, lv][:, :, None],
                                       axis=1) + w
            return jnp.where(emask[:, lv][:, :, None], cand,
                             -BIG).astype(jnp.float32)

        if not want_lam:
            def body(lv, t_end):
                ts = maxplus_matvec_batched(A[:, lv], edge_cand(lv, t_end))
                ts = jnp.maximum(ts, 0.0)                    # [G, Vmax, S]
                return jax.lax.dynamic_update_slice(
                    t_end, ts + vcost_lv[:, lv][:, :, None], (0, lv * Vmax, 0))

            t_end = jax.lax.fori_loop(0, nlv, body,
                                      jnp.zeros((G, nflat, S), jnp.float32))
            T = jnp.max(jnp.where(valid_flat[:, :, None], t_end, -BIG), axis=1)
            return T, jnp.zeros((G, S, nc), jnp.float32)

        def body(lv, carry):
            t_end, ssum, chosen_all = carry
            cand = edge_cand(lv, t_end)
            cs = (jnp.take_along_axis(ssum, esrc[:, lv][:, :, None], axis=1)
                  + elat_sum[:, lv][:, :, None]).astype(jnp.float32)
            raw, eidx = maxplus_matvec_argmax_batched(A[:, lv], cand, cs)
            ts = jnp.maximum(raw, 0.0)                       # [G, Vmax, S]
            chosen = jnp.where(raw >= 0.0, eidx, -1)
            e_s = jnp.where(chosen >= 0, chosen, 0)
            src_slot = jnp.take_along_axis(esrc[:, lv][:, :, None], e_s,
                                           axis=1)           # [G, Vmax, S]
            gss = jnp.take_along_axis(ssum, src_slot, axis=1)
            ces = jnp.take_along_axis(elat_sum[:, lv][:, :, None], e_s,
                                      axis=1)
            ss_new = jnp.where(chosen >= 0, gss + ces, 0.0)
            off = lv * Vmax
            return (jax.lax.dynamic_update_slice(
                        t_end, ts + vcost_lv[:, lv][:, :, None], (0, off, 0)),
                    jax.lax.dynamic_update_slice(ssum, ss_new, (0, off, 0)),
                    jax.lax.dynamic_update_slice(chosen_all, chosen[:, None],
                                                 (0, lv, 0, 0)))

        init = (jnp.zeros((G, nflat, S), jnp.float32),
                jnp.zeros((G, nflat, S), jnp.float32),
                jnp.full((G, nlv, Vmax, S), -1, jnp.int32))
        t_end, ssum, chosen_all = jax.lax.fori_loop(0, nlv, body, init)
        T = jnp.max(jnp.where(valid_flat[:, :, None], t_end, -BIG), axis=1)
        sink = valid_flat[:, :, None] & (t_end >= T[:, None, :])
        mx = jnp.max(jnp.where(sink, ssum, -BIG), axis=1)
        top = sink & (ssum >= mx[:, None, :])
        vsel = jnp.argmin(jnp.where(top, vert_of_slot[:, :, None],
                                    jnp.iinfo(jnp.int32).max), axis=1)

        def back(i, carry):
            cur, lam = carry                                  # [G, S], [G, S, nc]
            lv = nlv - 1 - i
            onlvl = (cur >= lv * Vmax) & (cur < (lv + 1) * Vmax)
            off = jnp.where(onlvl, cur - lv * Vmax, 0)
            e = jnp.take_along_axis(chosen_all[:, lv], off[:, None, :],
                                    axis=1)[:, 0, :]          # [G, S]
            take = onlvl & (e >= 0)
            e_s = jnp.where(take, e, 0)
            rows = jnp.take_along_axis(elat[:, lv], e_s[:, :, None],
                                       axis=1)                # [G, S, nc]
            lam = lam + jnp.where(take[:, :, None], rows, 0.0)
            cur = jnp.where(take,
                            jnp.take_along_axis(esrc[:, lv], e_s, axis=1),
                            cur)
            return cur, lam

        _, lam = jax.lax.fori_loop(
            0, nlv, back,
            (vsel.astype(jnp.int32), jnp.zeros((G, S, nc), jnp.float32)))
        return T, lam

    return fwd


def _make_sparse_one(want_lam: bool, Emax_lv: int, Vmax_lv: int):
    """The single-scenario sparse (slot-list) forward.

    Levels are walked with fixed ``[Emax_lv]`` windows of the level-sorted
    edge lists and ``[Vmax_lv]`` vertex windows; the level scatter-max is a
    ``segment_max`` over window-local destinations (``edst − v_ptr[lv]``,
    computed in-kernel).  :class:`~repro.sweep.compile.SparsePlan`'s
    padding invariants make the windows safe: real levels never clamp,
    padded levels' windows touch only pad slots, and pad/foreign edges
    land at window-local destinations ≥ the destination level's true size
    — overrun writes into later-level slots are overwritten by that
    level's own full-window write before anything reads them, and
    out-of-window destinations are dropped by scatter OOB semantics.

    λ mirrors the segment backend's two-pass backtrace with the argmax in
    the *edge* domain: among value hits (within ATOL of the level max),
    max cumulative slope, then max global edge index — which, with edges
    sorted by (destination level, destination, original id), IS the max
    in-edge ordinal.  Same float64 op sequences per path ⇒ T and λ are
    bit-identical to ``segment``.
    """
    jax = _jax()
    jnp = jax.numpy
    dus = jax.lax.dynamic_update_slice
    dsl = jax.lax.dynamic_slice

    def one(esrc, edst, emask, econst, egap, egclass, elat, elat_sum,
            vcost, valid, vert_of_slot, level_ptr, v_ptr, Lrow, gsrow):
        nlv = level_ptr.shape[0] - 1
        nv_p = vcost.shape[0]
        nc = elat.shape[1]
        eidx = jnp.arange(Emax_lv, dtype=jnp.int32)
        vidx = jnp.arange(Vmax_lv, dtype=jnp.int32)

        def relax(lv, t):
            e0 = level_ptr[lv]
            es = dsl(esrc, (e0,), (Emax_lv,))
            em = dsl(emask, (e0,), (Emax_lv,))
            w = (dsl(econst, (e0,), (Emax_lv,))
                 + dsl(egap, (e0,), (Emax_lv,))
                 * (gsrow[dsl(egclass, (e0,), (Emax_lv,))] - 1.0)
                 + dsl(elat, (e0, jnp.int32(0)), (Emax_lv, nc)) @ Lrow)
            cand = jnp.where(em, t[es] + w, -BIG)
            dloc = dsl(edst, (e0,), (Emax_lv,)) - v_ptr[lv]
            seg = jax.ops.segment_max(cand, dloc, num_segments=Vmax_lv)
            ts = jnp.maximum(seg, 0.0)
            return e0, es, em, cand, dloc, ts

        def vwin(lv):
            return dsl(vcost, (v_ptr[lv],), (Vmax_lv,))

        if not want_lam:
            def body(lv, t):
                _, _, _, _, _, ts = relax(lv, t)
                return dus(t, ts + vwin(lv), (v_ptr[lv],))

            t = jax.lax.fori_loop(0, nlv, body, jnp.zeros(nv_p))
            T = jnp.max(jnp.where(valid, t, -BIG))
            return T, jnp.zeros((nc,))

        def body(lv, carry):
            t, ssum, nxt, lrow = carry
            e0, es, em, cand, dloc, ts = relax(lv, t)
            dsafe = jnp.clip(dloc, 0, Vmax_lv - 1)
            hit = em & (cand >= ts[dsafe] - ATOL)
            cs = ssum[es] + dsl(elat_sum, (e0,), (Emax_lv,))
            best = jax.ops.segment_max(jnp.where(hit, cs, -BIG), dloc,
                                       num_segments=Vmax_lv)
            sel = hit & (cs >= best[dsafe] - ATOL)
            chosen = jax.ops.segment_max(
                jnp.where(sel, e0 + eidx, -1), dloc,
                num_segments=Vmax_lv)
            has = chosen >= 0
            ce = jnp.where(has, chosen, 0)
            srcslot = esrc[ce]
            ss_new = jnp.where(has, ssum[srcslot] + elat_sum[ce], 0.0)
            own = v_ptr[lv] + vidx
            nxt_row = jnp.where(has, srcslot, own).astype(jnp.int32)
            row = jnp.where(has[:, None], elat[ce], 0.0)
            v0 = v_ptr[lv]
            return (dus(t, ts + vwin(lv), (v0,)),
                    dus(ssum, ss_new, (v0,)),
                    dus(nxt, nxt_row, (v0,)),
                    dus(lrow, row, (v0, jnp.int32(0))))

        init = (jnp.zeros(nv_p), jnp.zeros(nv_p),
                jnp.arange(nv_p, dtype=jnp.int32),
                jnp.zeros((nv_p, nc)))
        t, ssum, nxt, lrow = jax.lax.fori_loop(0, nlv, body, init)
        T = jnp.max(jnp.where(valid, t, -BIG))
        sink = valid & (t >= T - ATOL)
        mx = jnp.max(jnp.where(sink, ssum, -BIG))
        top = sink & (ssum >= mx)
        v = jnp.argmin(jnp.where(top, vert_of_slot,
                                 jnp.iinfo(jnp.int32).max))
        _, visited = jax.lax.scan(lambda cur, _: (nxt[cur], cur),
                                  jnp.int32(v), None, length=nlv)
        lam, _ = jax.lax.scan(lambda acc, r: (acc + r, 0.0),
                              jnp.zeros(nc), lrow[visited][::-1])
        return T, lam

    return one


def _sparse_core_axes(want_lam: bool, dims: tuple):
    """Sparse forward over S scenarios — the only batch axis the sparse
    backend populates (graphs past the dense cliff are evaluated solo).
    ``dims`` = (Emax_lv, Vmax_lv), the static window sizes."""
    jax = _jax()
    one = _make_sparse_one(want_lam, *dims)
    return jax.vmap(one, in_axes=(None,) * 13 + (0, 0))


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _sparse_pallas_core(want_lam: bool, dims: tuple):
    """Sparse slot-list forward through the Pallas kernel — the float32
    flavor of the sparse backend (``ExecPolicy(backend="sparse",
    dtype="float32")``).

    Same compact per-level windows as :func:`_make_sparse_one`, but the
    level scatter-max runs the slot-list (max,+) kernel with scenarios on
    the 128-wide lane axis (no per-scenario vmap) and the in-kernel
    lexicographic (value, cumulative-slope key, ordinal) argmax drives the
    λ backtrace — the sparse twin of ``_dense_core``.  Float32
    accumulators ⇒ T within ~1e-6 relative of the float64 slot-list
    forward; the same exact-tie caveat as the dense kernel applies
    (tolerance-grouped tie sets aren't associative across blocked
    reductions), so segment/sparse-f64 stay the bit-exact references.
    """
    jax = _jax()
    jnp = jax.numpy
    from repro.kernels.maxplus.ops import maxplus_slotlist_argmax

    Emax_lv, Vmax_lv = dims
    dsl = jax.lax.dynamic_slice
    dus = jax.lax.dynamic_update_slice
    E_pad = _round_up(Emax_lv, min(128, _round_up(Emax_lv, 8)))
    be = min(128, E_pad)
    E_pad = _round_up(E_pad, be)
    M_pad = _round_up(Vmax_lv, min(128, _round_up(Vmax_lv, 8)))
    bm = min(128, M_pad)
    M_pad = _round_up(M_pad, bm)

    def fwd(esrc, edst, emask, econst, egap, egclass, elat, elat_sum,
            vcost, valid, vert_of_slot, level_ptr, v_ptr, Lmat, GSmat):
        nlv = level_ptr.shape[0] - 1
        nv_p = vcost.shape[0]
        nc = elat.shape[1]
        S = Lmat.shape[0]
        vidx = jnp.arange(Vmax_lv, dtype=jnp.int32)

        def relax(lv, t):
            e0 = level_ptr[lv]
            es = dsl(esrc, (e0,), (Emax_lv,))
            em = dsl(emask, (e0,), (Emax_lv,))
            gcls = dsl(egclass, (e0,), (Emax_lv,))
            w = (dsl(econst, (e0,), (Emax_lv,))[:, None]
                 + dsl(egap, (e0,), (Emax_lv,))[:, None]
                 * (jnp.take(GSmat, gcls, axis=1).T - 1.0)
                 + dsl(elat, (e0, jnp.int32(0)), (Emax_lv, nc)) @ Lmat.T)
            cand = jnp.where(em[:, None], t[es] + w, -BIG)   # [Emax_lv, S]
            dloc = dsl(edst, (e0,), (Emax_lv,)) - v_ptr[lv]
            return e0, es, cand, dloc

        def reduce(cand, dloc, key):
            # pad to the kernel's block multiples; pad slots point past
            # every row (never hit), pad rows come back −∞/−1 and are
            # sliced off
            cf = jnp.pad(cand.astype(jnp.float32),
                         ((0, E_pad - Emax_lv), (0, 0)),
                         constant_values=-BIG)
            kf = jnp.pad(key.astype(jnp.float32),
                         ((0, E_pad - Emax_lv), (0, 0)))
            d = jnp.pad(dloc.astype(jnp.int32), (0, E_pad - Emax_lv),
                        constant_values=M_pad)[:, None]
            out, idx = maxplus_slotlist_argmax(d, cf, kf, M=M_pad,
                                               bm=bm, be=be)
            return out[:Vmax_lv], idx[:Vmax_lv]

        def vwin(lv):
            return dsl(vcost, (v_ptr[lv],), (Vmax_lv,))

        if not want_lam:
            def body(lv, t):
                _, _, cand, dloc = relax(lv, t)
                raw, _ = reduce(cand, dloc, jnp.zeros_like(cand))
                ts = jnp.maximum(raw, 0.0)
                return dus(t, (ts + vwin(lv)[:, None]).astype(jnp.float32),
                           (v_ptr[lv], jnp.int32(0)))

            t = jax.lax.fori_loop(0, nlv, body,
                                  jnp.zeros((nv_p, S), jnp.float32))
            T = jnp.max(jnp.where(valid[:, None], t, -BIG), axis=0)
            return T, jnp.zeros((S, nc), jnp.float32)

        def body(lv, carry):
            t, ssum, nxt, lrow = carry
            e0, es, cand, dloc = relax(lv, t)
            cs = (jnp.take(ssum, es, axis=0)
                  + dsl(elat_sum, (e0,), (Emax_lv,))[:, None])
            raw, eidx = reduce(cand, dloc, cs)               # [Vmax_lv, S]
            ts = jnp.maximum(raw, 0.0)
            has = (raw >= 0.0) & (eidx >= 0)
            ce = jnp.where(has, eidx, 0)
            srcslot = es[ce]                                 # [Vmax_lv, S]
            ss_new = jnp.where(
                has,
                jnp.take_along_axis(ssum, srcslot, axis=0)
                + dsl(elat_sum, (e0,), (Emax_lv,))[ce], 0.0)
            own = v_ptr[lv] + vidx
            nxt_row = jnp.where(has, srcslot, own[:, None]).astype(jnp.int32)
            elat_w = dsl(elat, (e0, jnp.int32(0)), (Emax_lv, nc))
            row = jnp.where(has[:, :, None], elat_w[ce], 0.0)
            v0 = v_ptr[lv]
            z = jnp.int32(0)
            return (dus(t, (ts + vwin(lv)[:, None]).astype(jnp.float32),
                        (v0, z)),
                    dus(ssum, ss_new.astype(jnp.float32), (v0, z)),
                    dus(nxt, nxt_row, (v0, z)),
                    dus(lrow, row.astype(jnp.float32), (v0, z, z)))

        init = (jnp.zeros((nv_p, S), jnp.float32),
                jnp.zeros((nv_p, S), jnp.float32),
                jnp.broadcast_to(jnp.arange(nv_p, dtype=jnp.int32)[:, None],
                                 (nv_p, S)),
                jnp.zeros((nv_p, S, nc), jnp.float32))
        t, ssum, nxt, lrow = jax.lax.fori_loop(0, nlv, body, init)
        T = jnp.max(jnp.where(valid[:, None], t, -BIG), axis=0)
        sink = valid[:, None] & (t >= T[None, :])
        mx = jnp.max(jnp.where(sink, ssum, -BIG), axis=0)
        top = sink & (ssum >= mx[None, :])
        vsel = jnp.argmin(jnp.where(top, vert_of_slot[:, None],
                                    jnp.iinfo(jnp.int32).max), axis=0)
        sidx = jnp.arange(S)

        def step(cur, _):
            return nxt[cur, sidx], cur

        _, visited = jax.lax.scan(step, vsel.astype(jnp.int32), None,
                                  length=nlv)                # [nlv, S]
        lam = jnp.sum(lrow[visited, sidx[None, :], :], axis=0)
        return T, lam

    return fwd


_FWD_CACHE: dict = {}
_MESHES: dict = {}


def _device_mesh(ndev: int):
    """1-D device mesh over the first ``ndev`` local devices (cached)."""
    jax = _jax()
    if ndev not in _MESHES:
        _MESHES[ndev] = jax.sharding.Mesh(
            np.asarray(jax.devices()[:ndev]), ("x",))
    return _MESHES[ndev]


def _resolve_shard(shard, size: int) -> Optional[int]:
    """Normalize a ``shard=`` request to a device count that divides the
    batch axis (None = unsharded).  ``True``/"auto" = all local devices;
    an int = at most that many.  The count is walked down to the largest
    divisor of ``size`` so sharded and single-device runs stay bit-equal
    (no pad rows, no uneven splits)."""
    if not shard:
        return None
    jax = _jax()
    avail = len(jax.devices())
    ndev = avail if shard is True or shard == "auto" else min(int(shard), avail)
    ndev = max(min(ndev, size), 1)
    while size % ndev:
        ndev -= 1
    return ndev if ndev > 1 else None


def _stage_arrays(plan, kind: str, max_dense_bytes: int) -> tuple:
    """Device-stage a plan's tensors for one backend.  CompiledPlan and
    MultiPlan share field names (the latter just carries a leading graph
    axis), so both engines stage through this one helper."""
    jnp = _jax().numpy
    if kind == "segment":
        return tuple(jnp.asarray(a) for a in (
            plan.vsrc, plan.vmaskd, plan.vconst, plan.vgap, plan.vgclass,
            plan.vlat, plan.vlat_sum, plan.vcost_lv, plan.valid_flat,
            plan.vert_of_slot))
    if kind == "sparse":
        return tuple(jnp.asarray(a) for a in (
            plan.esrc_slot, plan.edst_slot, plan.emask, plan.econst,
            plan.egap, plan.egclass, plan.elat, plan.elat_sum, plan.vcost,
            plan.valid, plan.vert_of_slot, plan.level_ptr, plan.v_ptr))
    if kind == "congestion":
        if plan.vlink is None:
            raise ValueError(
                "congestion needs per-edge link ids, but this plan carries "
                "none (the graph was built without link interning — use "
                "GraphBuilder.add_message / intern_link, or recompile from "
                "a graph with elink populated)")
        # link bins: [0, nlinks) real links, nlinks = dummy (dep edges,
        # pad slots), bucketed up to Lp; masked bins keep lscale ≡ 1.0
        Lp = _bucket(plan.nlinks + 1, lo=8)
        link_cls = np.zeros(Lp, dtype=np.int32)
        if plan.link_classes is not None and plan.nlinks:
            link_cls[:plan.nlinks] = plan.link_classes
        link_mask = np.arange(Lp) < plan.nlinks
        return tuple(jnp.asarray(a) for a in (
            plan.vsrc, plan.vmaskd, plan.vconst, plan.vgap, plan.vgclass,
            plan.vlat, plan.vlat_sum, plan.vcost_lv, plan.valid_flat,
            plan.vert_of_slot, plan.vlink, link_cls, link_mask))
    if plan.dense_bytes() > max_dense_bytes:
        raise ValueError(
            f"dense pallas backend needs {plan.dense_bytes() >> 20} MiB "
            f"of indicator tensors (> {max_dense_bytes >> 20}); "
            "use backend='segment' or backend='sparse'")
    return tuple(jnp.asarray(a) for a in (
        plan.dense_indicator(-BIG), plan.esrc, plan.emask,
        plan.econst.astype(np.float32), plan.egap.astype(np.float32),
        plan.egclass, plan.elat.astype(np.float32),
        plan.vcost_lv.astype(np.float32), plan.valid_flat,
        plan.vert_of_slot))


#: positional plan args every core takes ahead of (Lmat, GSmat)
_N_PLAN_ARGS = 10


def _shard_specs(kind: str, multi: bool, costs: Optional[tuple],
                 shard_axis: str) -> tuple:
    """Per-argument shard_map partition specs for one populated-axis cell.

    Every forward takes ``_N_PLAN_ARGS`` plan tensors + (L, GS); the dim
    that carries ``shard_axis`` differs per argument and per backend:

    * "S" — only the scenario tensors split (dim 1 under a graph axis);
    * "G" — every tensor splits on its graph dim (0 everywhere, except
      pallas patched cost tensors, which are staged [K, G, ...]);
    * "K" — only the *patched* cost tensors split on their candidate dim
      (structure, unpatched costs and scenarios replicate).

    Output layouts: segment [G?, K?, S], pallas [K?, G?, S].
    """
    P = _jax().sharding.PartitionSpec

    def spec(d):
        return P() if d is None else P(*([None] * d + ["x"]))

    K = costs is not None
    dims: list = [None] * (_N_PLAN_ARGS + 2)
    cost0 = 2 if kind == "segment" else 3      # first cost-field position
    if shard_axis == "S":
        dims[10] = dims[11] = 1 if multi else 0
    elif shard_axis == "G":
        dims = [0] * (_N_PLAN_ARGS + 2)
        if K and kind == "pallas":             # patched costs are [K, G, ...]
            for j, ax in enumerate(costs):
                if ax == 0:
                    dims[cost0 + j] = 1
    else:                                      # "K"
        for j, ax in enumerate(costs):
            if ax == 0:
                dims[cost0 + j] = (1 if multi else 0) \
                    if kind == "segment" else 0
    if kind == "segment":
        od = {"G": 0, "K": 1 if multi else 0,
              "S": int(multi) + int(K)}[shard_axis]
    else:
        od = {"K": 0, "G": 1 if K else 0,
              "S": int(multi) + int(K)}[shard_axis]
    return tuple(spec(d) for d in dims), (spec(od), spec(od))


def _get_forward(kind: str, want_lam: bool = False, multi: bool = False,
                 fused: bool = False, mesh=None,
                 costs: Optional[tuple] = None,
                 shard_axis: Optional[str] = None,
                 structure: Optional[tuple] = None,
                 sparse_dims: Optional[tuple] = None):
    """Build (or fetch) the jitted forward for one populated-axis cell.

    The cell is keyed on (backend, λ, G axis, K axes, mesh, shard axis):
    vmap composition over the populated batch axes is derived here
    (``_segment_core_axes`` / ``_dense_core_axes``) rather than from which
    engine class a caller instantiated — graph [G], candidate-cost [K] and
    scenario [S] axes compose freely, including all at once.

    With ``mesh`` the composed core is wrapped in ``shard_map`` before
    jit; ``shard_axis`` picks which populated axis splits across devices
    (default: the MultiPlan graph axis when present, else scenarios — the
    legacy engines' behavior).  Per-element arithmetic is unchanged either
    way, so sharded results are bit-identical to single-device runs.

    ``costs`` (a per-cost-field vmap-axis tuple, see ``_SEG_COST_FIELDS``
    / ``_PAL_COST_FIELDS``) selects the candidate-cost-axis cells:
    patched cost tensors batched, structure and unpatched costs unbatched,
    scenarios broadcast.
    """
    jax = _jax()
    mesh_key = None if mesh is None else tuple(
        d.id for d in np.asarray(mesh.devices).flat)
    fused = bool(fused and want_lam and kind == "segment")
    if kind in ("sparse", "sparse_pallas"):
        if multi or costs is not None or structure is not None:
            raise ValueError("sparse backend populates the scenario axis "
                             "only (no G/K/B batching yet)")
        if mesh is not None:
            raise ValueError("sparse backend does not shard yet")
        if sparse_dims is None:
            raise ValueError("sparse forward needs sparse_dims="
                             "(Emax_lv, Vmax_lv)")
    if kind == "congestion":
        if multi or structure is not None:
            raise ValueError("the congestion fixed point populates the S "
                             "and K axes only (no G/B batching)")
        if mesh is not None:
            raise ValueError("the congestion fixed point does not shard "
                             "yet (while_loop lanes must stay lockstep on "
                             "one device)")
    if structure is not None and multi:
        raise ValueError("structure blocks and a MultiPlan graph axis "
                         "cannot combine (pick one variant axis)")
    if structure is not None and mesh is not None:
        raise ValueError("sharding a structure-batched query is not "
                         "supported yet")
    if mesh is None:
        shard_axis = None
    elif shard_axis is None:
        shard_axis = "G" if multi else "S"
    if shard_axis == "G" and not multi:
        raise ValueError("shard_axis='G' needs a multi-graph forward "
                         "(no graph axis is populated)")
    if shard_axis == "K" and costs is None:
        raise ValueError("shard_axis='K' needs a cost-batched forward "
                         "(no candidate axis is populated)")
    key = (kind, want_lam, multi, fused, mesh_key, costs, shard_axis,
           structure, sparse_dims)
    if key in _FWD_CACHE:
        return _FWD_CACHE[key]
    if kind == "segment":
        core = _segment_core_axes(want_lam, multi, costs, fused, structure)
    elif kind == "congestion":
        core = _congestion_core_axes(want_lam, costs)
    elif kind == "sparse":
        core = _sparse_core_axes(want_lam, sparse_dims)
    elif kind == "sparse_pallas":
        core = _sparse_pallas_core(want_lam, sparse_dims)
    else:
        core = _dense_core_axes(want_lam, multi, costs, structure)
    if mesh is not None:
        from jax.experimental.shard_map import shard_map
        in_specs, out_specs = _shard_specs(kind, multi, costs, shard_axis)
        core = shard_map(core, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
    fn = jax.jit(core)
    _FWD_CACHE[key] = fn
    return fn


def _warn_deprecated_shim(old: str) -> None:
    import warnings
    warnings.warn(
        f"{old} is deprecated; build a repro.sweep.Engine with an "
        "ExecPolicy and run a Query instead (one engine, G/K/S batch "
        "axes — see repro.sweep.api).  This shim delegates to the unified "
        "engine and stays bit-identical.",
        DeprecationWarning, stacklevel=3)


class SweepEngine:
    """DEPRECATED shim over :class:`repro.sweep.api.Engine` (single graph).

    Compile once, evaluate thousands of LogGPS scenarios per call:

    >>> eng = SweepEngine(graph, params)
    >>> res = eng.run(latency_grid(params, np.linspace(0, 100, 1000)))
    >>> res.T, res.lam, res.rho     # [1000], [1000, nclass], [1000, nclass]

    The unified engine dispatches the *same* jit cells this class used to
    own, so results (λ tie-breaks included) are bit-identical; new code
    should construct ``Engine``/``Query``/``ExecPolicy`` directly.
    """

    MAX_DENSE_BYTES = 256 << 20

    def __init__(self, graph=None, params: Optional[LogGPS] = None,
                 backend: str = "segment", shard=None,
                 compiled: Optional[CompiledPlan] = None,
                 cache: Optional[SweepCache] = DEFAULT_CACHE):
        _warn_deprecated_shim("SweepEngine")
        from .api import Engine, ExecPolicy
        if compiled is None:
            if graph is None:
                raise ValueError("need a graph or a CompiledPlan")
            compiled = compile_plan(graph, params)
        self._eng = Engine(compiled, params=params,
                           policy=ExecPolicy(backend=backend, shard=shard,
                                             cache=cache))
        # honor a subclass/class-level override of the dense-size guard
        self._eng.MAX_DENSE_BYTES = type(self).MAX_DENSE_BYTES

    # -- legacy attribute surface (read-through to the unified engine) -------
    @property
    def compiled(self) -> CompiledPlan:
        return self._eng.plan

    @property
    def params(self):
        return self._eng.params

    @property
    def backend(self) -> str:
        return self._eng.policy.backend

    @property
    def shard(self):
        return self._eng.policy.shard

    @property
    def cache(self):
        return self._eng.policy.cache

    @property
    def calls(self) -> int:
        return self._eng.calls

    def _arrays(self, kind: str):
        return self._eng._arrays(kind)

    def run(self, scenarios: ScenarioBatch, compute_lam: bool = True,
            backend: Optional[str] = None, shard=None,
            use_cache: bool = True, costs: Optional[CostBatch] = None):
        """Evaluate every scenario; returns numpy-backed :class:`SweepResult`
        (or :class:`CostSweepResult` when ``costs`` populates the candidate
        axis).  ``shard`` now composes with ``costs`` — the unified engine
        shards whichever axis the policy picks (scenarios by default)."""
        res = self._eng.run(scenarios=scenarios, compute_lam=compute_lam,
                            backend=backend, shard=shard,
                            use_cache=use_cache, costs=costs)
        if "K" in res.axes:
            return CostSweepResult(T=res.T, lam=res.lam, rho=res.rho,
                                   scenarios=res.scenarios,
                                   backend=res.backend,
                                   from_cache=res.from_cache)
        return SweepResult(T=res.T, lam=res.lam, rho=res.rho,
                           scenarios=res.scenarios, backend=res.backend,
                           from_cache=res.from_cache)

    def latency_curve(self, deltas: Sequence[float], cls: int = 0,
                      params: Optional[LogGPS] = None,
                      compute_lam: bool = True) -> SweepResult:
        p = params or self.params
        if p is None:
            raise ValueError("engine has no params; pass params=")
        return self.run(latency_grid(p, deltas, cls=cls),
                        compute_lam=compute_lam)


# -- multi-graph engine: (graph × scenario) in one compiled program -----------

@dataclasses.dataclass
class MultiSweepResult:
    """Per-graph sweep tensors: row g is graph g's :class:`SweepResult`."""

    T: np.ndarray                    # [G, S] µs
    lam: Optional[np.ndarray]        # [G, S, nclass] or None
    rho: Optional[np.ndarray]        # [G, S, nclass] or None
    scenarios: list                  # per-graph ScenarioBatch
    names: tuple
    backend: str
    from_cache: bool = False

    @property
    def G(self) -> int:
        return int(self.T.shape[0])

    @property
    def S(self) -> int:
        return int(self.T.shape[1])

    def __getitem__(self, key) -> SweepResult:
        """Graph g's slice as a plain :class:`SweepResult` (by index or name)."""
        g = self.names.index(key) if isinstance(key, str) else int(key)
        return SweepResult(
            T=self.T[g].copy(),
            lam=None if self.lam is None else self.lam[g].copy(),
            rho=None if self.rho is None else self.rho[g].copy(),
            scenarios=self.scenarios[g], backend=self.backend,
            from_cache=self.from_cache)

    def split(self) -> dict:
        """{name: SweepResult} — the ``sweep_variants`` return shape."""
        return {name: self[i] for i, name in enumerate(self.names)}

    def rank(self, reduce: str = "mean") -> list:
        """Variants ordered best-first by makespan over the grid.

        ``reduce``: 'mean' | 'max' | 'final' (last scenario row — e.g. the
        worst latency point of an ascending grid).
        """
        if reduce == "mean":
            obj = self.T.mean(axis=1)
        elif reduce == "max":
            obj = self.T.max(axis=1)
        elif reduce == "final":
            obj = self.T[:, -1]
        else:
            raise ValueError(f"unknown reduce {reduce!r}")
        order = np.argsort(obj, kind="stable")
        return [(self.names[i], float(obj[i])) for i in order]


class MultiSweepEngine:
    """DEPRECATED shim over :class:`repro.sweep.api.Engine` (graph axis).

    Evaluate G packed graphs × S scenarios in one compiled program:

    >>> eng = MultiSweepEngine([(v.graph, v.params) for v in variants],
    ...                        names=[v.name for v in variants])
    >>> res = eng.run(sweep.latency_grid(params, deltas))   # broadcast grid
    >>> res.T.shape, res["algo=ring"].T.shape               # [G, S], [S]

    Bit-identical to the unified engine (same jit cells); new code should
    build ``Engine([plans...])`` directly — which also unlocks what this
    class never supported: ``run(costs=)`` per-graph candidate axes and
    sharding over any populated axis.
    """

    MAX_DENSE_BYTES = SweepEngine.MAX_DENSE_BYTES

    def __init__(self, graphs_params=None, names=None,
                 backend: str = "segment", shard=None,
                 multi: Optional[MultiPlan] = None,
                 cache: Optional[SweepCache] = DEFAULT_CACHE):
        _warn_deprecated_shim("MultiSweepEngine")
        from .api import Engine, ExecPolicy
        pol = ExecPolicy(backend=backend, shard=shard, cache=cache)
        if multi is None:
            if not graphs_params:
                raise ValueError("need (graph, params) pairs or a MultiPlan")
            self._eng = Engine(list(graphs_params), policy=pol, names=names)
            self.params = [p for _, p in graphs_params]
        else:
            self._eng = Engine(multi, policy=pol, names=names)
            self.params = [None] * multi.G
        # honor a subclass/class-level override of the dense-size guard
        self._eng.MAX_DENSE_BYTES = type(self).MAX_DENSE_BYTES

    @classmethod
    def from_variants(cls, variants, **kw):
        """Build from :class:`~repro.sweep.scenarios.GraphVariant`\\ s (which
        must share one latency-class count — pre-group with
        :func:`~repro.sweep.compile.group_plans` otherwise)."""
        return cls([(v.graph, v.params) for v in variants],
                   names=[v.name for v in variants], **kw)

    # -- legacy attribute surface --------------------------------------------
    @property
    def multi(self) -> MultiPlan:
        return self._eng.multi

    @property
    def names(self) -> tuple:
        return self._eng.names

    @names.setter
    def names(self, value) -> None:
        self._eng.names = tuple(value)

    @property
    def backend(self) -> str:
        return self._eng.policy.backend

    @property
    def shard(self):
        return self._eng.policy.shard

    @property
    def cache(self):
        return self._eng.policy.cache

    @property
    def calls(self) -> int:
        return self._eng.calls

    def _arrays(self, kind: str):
        return self._eng._arrays(kind)

    def run(self, scenarios, compute_lam: bool = True,
            backend: Optional[str] = None, shard=None,
            use_cache: bool = True, costs=None):
        """One compiled call → :class:`MultiSweepResult` over every graph.

        ``scenarios``: one :class:`ScenarioBatch` (broadcast to all graphs)
        or a per-graph sequence with equal S (variant studies whose base
        parameter points differ).  ``backend="pallas"`` returns λ/ρ directly
        (batched argmax kernel).  ``shard`` splits the MultiPlan's leading
        graph axis across local devices via ``shard_map`` — the natural
        mesh axis; results stay bit-identical to the single-device run.

        ``costs`` (one cost batch / raw ``[K, ne]`` extras array per
        graph) populates the candidate axis alongside the graph axis — a
        capability the legacy engine never had; the result is then the
        unified :class:`repro.sweep.api.Result` with ``T[G, K, S]``.
        """
        res = self._eng.run(scenarios=scenarios, compute_lam=compute_lam,
                            backend=backend, shard=shard,
                            use_cache=use_cache, costs=costs)
        if "K" in res.axes:
            return res
        return MultiSweepResult(T=res.T, lam=res.lam, rho=res.rho,
                                scenarios=res.scenarios, names=res.names,
                                backend=res.backend,
                                from_cache=res.from_cache)


# -- lockstep-batched bisections (the dag.py loops, one engine call/round) ----

def _probe(eng: SweepEngine, params: LogGPS, Lvals, cls: int,
           backend: Optional[str] = None):
    batch = latency_grid(params, np.asarray(Lvals, dtype=np.float64),
                         cls=cls, absolute=True)
    res = eng.run(batch, compute_lam=True, use_cache=False, backend=backend)
    return res.T, res.lam[:, cls]


def tolerance_batched(eng: SweepEngine, params: LogGPS,
                      degradations: Sequence[float], cls: int = 0,
                      L_hi: float = 1e7, tol: float = 1e-6,
                      max_iter: int = 200,
                      backend: Optional[str] = None) -> dict:
    """All of ``dag.tolerance``'s bisections in lockstep: each round probes
    every still-active degradation level in one batched forward."""
    degr = np.asarray(list(degradations), dtype=np.float64)
    S = degr.shape[0]
    L0 = float(params.L[cls])
    T0 = _probe(eng, params, [L0], cls, backend)[0][0]
    budgets = (1.0 + degr) * T0
    Thi = _probe(eng, params, [L_hi], cls, backend)[0][0]

    out = np.empty(S)
    done = Thi <= budgets
    out[done] = np.inf
    a = np.full(S, L0)
    b = np.full(S, L_hi)
    for _ in range(max_iter):
        act = np.nonzero(~done)[0]
        if act.size == 0:
            break
        Tb, lb = _probe(eng, params, b[act], cls, backend)
        x = np.where(lb > 0, b[act] + (budgets[act] - Tb) / np.where(lb > 0, lb, 1.0),
                     (a[act] + b[act]) / 2)
        x = np.clip(x, a[act], b[act])
        Tx, _ = _probe(eng, params, x, cls, backend)
        conv = np.abs(Tx - budgets[act]) <= tol * np.maximum(1.0, budgets[act])
        out[act[conv]] = x[conv] - L0
        done[act[conv]] = True
        rest = act[~conv]
        hi = Tx[~conv] > budgets[rest]
        b[rest[hi]] = x[~conv][hi]
        a[rest[~hi]] = x[~conv][~hi]
        narrow = ~done & (b - a < tol)
        out[narrow] = a[narrow] - L0
        done |= narrow
    out[~done] = a[~done] - L0
    return {float(p): float(v) for p, v in zip(degr, out)}


def breakpoints_batched(eng: SweepEngine, params: LogGPS, L_min: float,
                        L_max: float, cls: int = 0, tol: float = 1e-9,
                        max_bp: int = 10_000, max_depth: int = 80,
                        backend: Optional[str] = None) -> list:
    """``dag.breakpoints`` with the recursion flattened level-by-level: all
    frontier intervals' probe points are evaluated in one batched call."""
    (ya, yb), (sa, sb) = _probe(eng, params, [L_min, L_max], cls, backend)
    frontier = [(L_min, float(ya), float(sa), L_max, float(yb), float(sb), 0)]
    out: list = []
    while frontier and len(out) < max_bp:
        work = [iv for iv in frontier
                if abs(iv[2] - iv[5]) > tol and iv[6] <= max_depth]
        if not work:
            break
        xs = []
        for (A, yA, sA, B, yB, sB, _) in work:
            x = (yB - sB * B - (yA - sA * A)) / (sA - sB)
            xs.append(min(max(x, A + tol), B - tol))
        ys, ss = _probe(eng, params, xs, cls, backend)
        frontier = []
        for (A, yA, sA, B, yB, sB, d), x, yx, sx in zip(work, xs, ys, ss):
            if len(out) >= max_bp:
                break
            line = yA + sA * (x - A)
            if yx <= line + max(1e-7, 1e-9 * abs(line)):
                out.append(float(x))
            else:
                frontier.append((A, yA, sA, float(x), float(yx), float(sx), d + 1))
                frontier.append((float(x), float(yx), float(sx), B, yB, sB, d + 1))
    return sorted(out)
