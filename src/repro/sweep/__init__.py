"""repro.sweep — batched scenario-sweep engine (JAX/Pallas max-plus).

LLAMP's workhorse loop is "re-evaluate execution graphs under many LogGPS
parameter points" (latency curves, tolerance bisections, the Algorithm-2
breakpoint search, collective/topology variant studies).  The scalar path
pays a full Python/numpy level walk per point; this subsystem compiles
graphs ONCE into padded dense per-level tensors and evaluates whole grids
in single jit+vmap max-plus forward passes — batching over scenarios, and
over *(graphs × scenarios)* for variant studies:

    from repro import sweep

    # one graph × many scenarios
    eng  = sweep.SweepEngine(graph, params)          # compile once
    grid = sweep.latency_grid(params, deltas)        # or cartesian_grid(...)
    res  = eng.run(grid)                             # T/λ/ρ for every scenario

    # many graphs × many scenarios (one compiled program per shape bucket)
    variants = sweep.collective_variants(factory, algos, params)
    out = sweep.sweep_variants(variants, lambda v: grid)   # {name: SweepResult}

    meng = sweep.MultiSweepEngine.from_variants(variants)  # explicit control
    multi = meng.run(grid)                                 # T[G, S]; .rank()

Public surface (re-exported here):
    SweepEngine / SweepResult         — one graph, S scenarios per call
    MultiSweepEngine / MultiSweepResult — G packed graphs × S scenarios per call
    CompiledPlan / compile_plan       — graph → bucketed rectangular tensors
                                        (immutable structure + patchable
                                        cost block, see COST_FIELDS)
    CostBatch / CompiledPlan.patch_costs — K candidate cost blocks for one
                                        plan structure; run(costs=...) adds
                                        the candidate axis with zero
                                        recompiles (CostSweepResult [K, S])
    MultiPlan / pack_plans / group_plans — pad plans to a common envelope and
                                        stack them on a leading graph axis
    ScenarioBatch + grid builders     — latency_grid / bandwidth_grid /
                                        cartesian_grid / base_batch
    GraphVariant stamping             — collective_variants / topology_variants
                                        / sweep_variants (axes that change the
                                        graph itself)
    tolerance_batched / breakpoints_batched — dag.py's bisection loops in
                                        lockstep, one engine call per round
    SweepCache / DEFAULT_CACHE        — content-hash LRU memo of results
                                        (canonical-bytes keys, process-stable)

Results match ``core.dag`` exactly (same argmax tie-breaks, float64) — a
graph packed into a MultiPlan returns bit-identical T/λ to its solo run —
and λ matches the explicit LP's reduced costs; ``core.sensitivity``
dispatches here automatically for multi-point sweeps.  The Pallas
``maxplus`` kernel is the inner-scatter backend (``backend="pallas"``;
graphs ride the kernel's outer grid axis in the batched variant) and
serves λ/ρ natively via its argmax-emitting variant — no segment
redispatch.  ``run(shard=...)`` splits the scenario axis (single graph)
or the MultiPlan graph axis (packed) across local devices with
``shard_map``, bit-equal to single-device runs.
``launch.analysis.AnalysisService`` serves what-if queries over warm
engines built from these pieces (per-request backend/shard).
"""

from .cache import DEFAULT_CACHE, SweepCache, canonical_bytes  # noqa: F401
from .compile import (COST_FIELDS, CompiledPlan, CostBatch,  # noqa: F401
                      MultiPlan, compile_plan, group_plans, pack_plans,
                      repad_plan)
from .engine import (CostSweepResult, MultiSweepEngine,  # noqa: F401
                     MultiSweepResult, SweepEngine, SweepResult,
                     breakpoints_batched, tolerance_batched)
from .scenarios import (GraphVariant, ScenarioBatch, bandwidth_grid,  # noqa: F401
                        base_batch, cartesian_grid, collective_variants,
                        latency_grid, sweep_variants, topology_variants)
