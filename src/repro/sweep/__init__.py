"""repro.sweep — batched scenario-sweep engine (JAX/Pallas max-plus).

LLAMP's workhorse loop is "re-evaluate execution graphs under many LogGPS
parameter points" (latency curves, tolerance bisections, the Algorithm-2
breakpoint search, collective/topology variant studies, placement
candidate evaluation).  The scalar path pays a full Python/numpy level
walk per point; this subsystem compiles graphs ONCE into padded dense
per-level tensors and evaluates whole grids in single jit+vmap max-plus
forward passes.

**One engine, four axes.**  Every sweep is one :class:`~repro.sweep.api.
Engine` evaluating a :class:`~repro.sweep.api.Query` whose populated batch
axes — graphs [G] × structural variants [B] × candidate cost blocks [K] ×
scenarios [S] — compose freely (G and B are mutually exclusive leading
axes), under an :class:`~repro.sweep.api.ExecPolicy` (backend, device
sharding over any populated axis, exact-vs-finite-difference λ, cache):

    from repro import sweep

    # one graph × many scenarios
    eng  = sweep.Engine(graph, params=params)        # compile once
    grid = sweep.latency_grid(params, deltas)        # or cartesian_grid(...)
    res  = eng.run(grid)                             # T/λ/ρ for every scenario

    # graphs × candidate costs × scenarios, sharded over any axis
    eng  = sweep.Engine([plan_a, plan_b],
                        policy=sweep.ExecPolicy(shard=True, shard_axis="K"))
    res  = eng.run(sweep.Query(scenarios=grid, costs=[extras_a, extras_b]))
    res.T.shape                                      # [G, K, S]

Public surface (re-exported here):
    Engine / Query / ExecPolicy / Result — the unified axis-oriented API
                                        (repro.sweep.api)
    SweepEngine / MultiSweepEngine    — DEPRECATED shims over Engine
                                        (bit-identical; DeprecationWarning)
    SweepResult / MultiSweepResult / CostSweepResult — legacy result shapes
    CompiledPlan / compile_plan       — graph → bucketed rectangular tensors
                                        (immutable structure + patchable
                                        cost block, see COST_FIELDS)
    CostBatch / CompiledPlan.patch_costs — K candidate cost blocks for one
                                        plan structure; the Query costs axis
                                        (zero recompiles)
    StructureBatch / CompiledPlan.patch_structure — B structural variants
                                        (edge rewirings, or separately
                                        compiled plans via ``from_plans``)
                                        inside one super-envelope; the Query
                                        structure axis (zero recompiles)
    SparsePlan / compile_sparse / estimate_dense_bytes — compact per-level
                                        slot lists for graphs whose dense
                                        envelope exceeds MAX_DENSE_BYTES
                                        (``ExecPolicy(backend="sparse")``;
                                        auto-selected off degree statistics)
    MultiPlan / pack_plans / group_plans — pad plans to a common envelope and
                                        stack them on a leading graph axis
    ScenarioBatch + grid builders     — latency_grid / bandwidth_grid /
                                        cartesian_grid / base_batch
    GraphVariant stamping             — collective_variants / topology_variants
                                        / sweep_variants (axes that change the
                                        graph itself)
    Fault & straggler scenarios       — StragglerFault / LinkFault /
                                        DeviceFault + fault_axes /
                                        recovery_cost_us: a fault
                                        distribution lowered onto the B/K/S
                                        axes as ONE batched Query
                                        (``sensitivity.resilience_curve``)
    tolerance_batched / breakpoints_batched — dag.py's bisection loops in
                                        lockstep, one engine call per round
    SweepCache / DEFAULT_CACHE        — content-hash LRU memo of results
                                        (canonical-bytes keys, process-stable)

Results match ``core.dag`` exactly (same argmax tie-breaks, float64) — a
graph packed on the G axis returns bit-identical T/λ to its solo run, and
a cost block patched on the K axis returns bit-identical results to a
plan rebuilt with those costs — and λ matches the explicit LP's reduced
costs; ``core.sensitivity`` dispatches here automatically for multi-point
sweeps (``policy=`` forwards an ExecPolicy).  The Pallas ``maxplus``
kernel is the inner-scatter backend (``ExecPolicy(backend="pallas")``;
graphs ride the kernel's outer grid axis) and serves λ/ρ natively via its
argmax-emitting variant.  ``ExecPolicy(lam="fd")`` trades the bit-exact λ
backtrace for finite-difference λ over an (nc+1)× expanded values grid —
the same compiled values program, compile ratio ~1.0.
``launch.analysis.AnalysisService`` serves what-if queries over warm
engines built from these pieces (per-request ``policy`` blocks), over
stdin/stdout JSON lines or a TCP/UNIX socket.
"""

from .api import (Engine, ExecPolicy, Query, Result,  # noqa: F401
                  detached_engine, detached_engine_stats, run)
from .cache import (DEFAULT_CACHE, SweepCache, canonical_bytes,  # noqa: F401
                    graph_content_key)
from .compile import (COST_FIELDS, STRUCT_FIELDS, CompiledPlan,  # noqa: F401
                      CostBatch, MultiPlan, SparsePlan, StructureBatch,
                      compile_plan, compile_sparse, estimate_dense_bytes,
                      group_plans, pack_plans, repad_plan)
from .engine import (CostSweepResult, MultiSweepEngine,  # noqa: F401
                     MultiSweepResult, SweepEngine, SweepResult,
                     breakpoints_batched, tolerance_batched)
from .scenarios import (DeviceFault, FaultAxes, GraphVariant,  # noqa: F401
                        LinkFault, ScenarioBatch, StragglerFault,
                        bandwidth_grid, base_batch, cartesian_grid,
                        collective_variants, fault_axes, latency_grid,
                        recovery_cost_us, sample_grid, sweep_variants,
                        topology_variants)
