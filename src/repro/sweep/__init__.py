"""repro.sweep — batched scenario-sweep engine (JAX/Pallas max-plus).

LLAMP's workhorse loop is "re-evaluate one execution graph under many
LogGPS parameter points" (latency curves, tolerance bisections, the
Algorithm-2 breakpoint search).  The scalar path pays a full Python/numpy
level walk per point; this subsystem compiles the graph ONCE into padded
dense per-level tensors and evaluates a whole scenario grid in a single
jit+vmap max-plus forward pass:

    from repro import sweep
    eng  = sweep.SweepEngine(graph, params)          # compile once
    grid = sweep.latency_grid(params, deltas)        # or cartesian_grid(...)
    res  = eng.run(grid)                             # T/λ/ρ for every scenario

Modules:
    compile    — LevelPlan → CompiledPlan (bucketed rectangular tensors)
    engine     — SweepEngine (+ tolerance_batched / breakpoints_batched)
    scenarios  — ScenarioBatch grids; GraphVariant stamping (collectives,
                 topologies) for axes that change the graph itself
    cache      — content-hash LRU memo of sweep results

Results match ``core.dag`` exactly (same argmax tie-breaks, float64), and
λ matches the explicit LP's reduced costs; ``core.sensitivity`` dispatches
here automatically for multi-point sweeps.  The Pallas ``maxplus`` kernel
is available as the inner-scatter backend (``backend="pallas"``).
"""

from .cache import DEFAULT_CACHE, SweepCache  # noqa: F401
from .compile import CompiledPlan, compile_plan  # noqa: F401
from .engine import (SweepEngine, SweepResult, breakpoints_batched,  # noqa: F401
                     tolerance_batched)
from .scenarios import (GraphVariant, ScenarioBatch, bandwidth_grid,  # noqa: F401
                        base_batch, cartesian_grid, collective_variants,
                        latency_grid, sweep_variants, topology_variants)
