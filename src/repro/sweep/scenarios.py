"""Scenario grids: the parameter axes a sweep fans out over.

A :class:`ScenarioBatch` is the engine's unit of work — S rows of
(L per class, bandwidth scale γ per class).  Grid builders produce batches:

    latency_grid     — ΔL sweep on one class (Fig 9 / Algorithm 2 probes)
    bandwidth_grid   — γ sweep on one class (G_eff = γ·G_build)
    cartesian_grid   — cartesian product of per-class ΔL and γ axes

Scenario axes that change the *graph* (collective algorithm, topology) are
stamped out as :class:`GraphVariant`s (reusing ``core.collectives`` /
``core.topology``); stack their compiled plans into a
:class:`~repro.sweep.compile.StructureBatch` and run
``Query(structure=...)`` — one compiled program for the whole study.
:func:`sweep_variants` remains as a deprecated shim over that path.
"""

from __future__ import annotations

import dataclasses
import itertools
import warnings
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core import topology as topo_mod
from repro.core.graph import ExecutionGraph
from repro.core.loggps import LogGPS, resolve_class


@dataclasses.dataclass
class ScenarioBatch:
    """S scenarios: absolute per-class latencies and bandwidth scales."""

    L: np.ndarray                      # [S, nclass] float64, absolute µs
    gscale: np.ndarray                 # [S, nclass] float64, γ (1 = build G)
    meta: Optional[list] = None        # per-scenario dicts (labels, axes)

    def __post_init__(self):
        self.L = np.atleast_2d(np.asarray(self.L, dtype=np.float64))
        self.gscale = np.atleast_2d(np.asarray(self.gscale, dtype=np.float64))
        # real exceptions, not asserts: shape/NaN bugs must surface under
        # ``python -O`` too, and a single non-finite row would poison the
        # whole batched forward (max-reductions propagate NaN everywhere)
        if self.L.shape != self.gscale.shape:
            raise ValueError(
                f"scenario L and gscale shapes disagree: L is {self.L.shape}, "
                f"gscale is {self.gscale.shape}")
        bad = ~(np.isfinite(self.L).all(axis=1)
                & np.isfinite(self.gscale).all(axis=1))
        if bad.any():
            rows = np.nonzero(bad)[0]
            shown = rows[:8].tolist()
            more = "" if rows.size <= 8 else f" (+{rows.size - 8} more)"
            raise ValueError(
                f"non-finite scenario rows {shown}{more}: "
                f"L={self.L[rows[0]]}, gscale={self.gscale[rows[0]]} — "
                "NaN/inf would poison every vertex the batched forward "
                "touches")

    @property
    def S(self) -> int:
        return int(self.L.shape[0])

    @property
    def nclass(self) -> int:
        return int(self.L.shape[1])

    def concat(self, other: "ScenarioBatch") -> "ScenarioBatch":
        meta = None
        if self.meta is not None and other.meta is not None:
            meta = list(self.meta) + list(other.meta)
        return ScenarioBatch(L=np.concatenate([self.L, other.L]),
                             gscale=np.concatenate([self.gscale, other.gscale]),
                             meta=meta)


def base_batch(params: LogGPS) -> ScenarioBatch:
    nc = params.nclass
    return ScenarioBatch(L=np.asarray([params.L]), gscale=np.ones((1, nc)),
                         meta=[{"delta": 0.0}])


def latency_grid(params: LogGPS, deltas: Sequence[float], cls=0,
                 absolute: bool = False) -> ScenarioBatch:
    """One scenario per ΔL (or absolute L with ``absolute=True``) on ``cls``
    (a class index, or a registered class name like ``"dcn"``)."""
    cls = resolve_class(params, cls)
    d = np.asarray(deltas, dtype=np.float64).ravel()
    S, nc = d.shape[0], params.nclass
    L = np.tile(np.asarray(params.L, dtype=np.float64), (S, 1))
    L[:, cls] = d if absolute else L[:, cls] + d
    return ScenarioBatch(L=L, gscale=np.ones((S, nc)),
                         meta=[{"cls": cls, "L": float(x)} for x in L[:, cls]])


def bandwidth_grid(params: LogGPS, gscales: Sequence[float],
                   cls=0) -> ScenarioBatch:
    """One scenario per bandwidth scale γ on ``cls`` (an index or a
    registered class name; γ>1 = slower links)."""
    cls = resolve_class(params, cls)
    gs = np.asarray(gscales, dtype=np.float64).ravel()
    S, nc = gs.shape[0], params.nclass
    L = np.tile(np.asarray(params.L, dtype=np.float64), (S, 1))
    G = np.ones((S, nc))
    G[:, cls] = gs
    return ScenarioBatch(L=L, gscale=G,
                         meta=[{"cls": cls, "gscale": float(x)} for x in gs])


def cartesian_grid(params: LogGPS,
                   lat_deltas: Optional[dict] = None,
                   gscales: Optional[dict] = None) -> ScenarioBatch:
    """Cartesian product of per-class ΔL axes × per-class γ axes.

    ``lat_deltas`` / ``gscales`` map class id (or registered class name,
    e.g. ``"dcn"``) → sequence of values; omitted classes stay at the base
    point.  E.g. a 2-class TPU sweep::

        cartesian_grid(p, lat_deltas={0: ici_dl, 1: dcn_dl}, gscales={1: gs})
    """
    nc = params.nclass
    axes, keys = [], []
    for kind, table in (("L", lat_deltas), ("G", gscales)):
        seen: dict = {}
        for c, vals in sorted((table or {}).items(),
                              key=lambda kv: resolve_class(params, kv[0])):
            idx = resolve_class(params, c)
            if idx in seen:
                # {1: [...], "dcn": [...]} on a model whose class 1 is
                # "dcn" would mint two axes writing the same column, the
                # later silently clobbering the earlier
                raise ValueError(
                    f"duplicate {'lat_deltas' if kind == 'L' else 'gscales'} "
                    f"axis: keys {seen[idx]!r} and {c!r} both resolve to "
                    f"class {idx} ({params.class_names[idx]!r})")
            seen[idx] = c
            axes.append(np.asarray(vals, dtype=np.float64))
            keys.append((kind, idx))
    if not axes:
        return base_batch(params)
    rows_L, rows_G, meta = [], [], []
    baseL = np.asarray(params.L, dtype=np.float64)
    for combo in itertools.product(*axes):
        L = baseL.copy()
        G = np.ones(nc)
        m = {}
        for (kind, c), v in zip(keys, combo):
            if kind == "L":
                L[c] = L[c] + v
                m[f"dL[{c}]"] = float(v)
            else:
                G[c] = v
                m[f"gscale[{c}]"] = float(v)
        rows_L.append(L)
        rows_G.append(G)
        meta.append(m)
    return ScenarioBatch(L=np.stack(rows_L), gscale=np.stack(rows_G), meta=meta)


def sample_grid(params: LogGPS, n: int, rng, *,
                lat_deltas: tuple = (0.0, 50.0),
                gscales: tuple = (1.0, 1.0), cls=0) -> ScenarioBatch:
    """``n`` randomly sampled scenarios on one class: ΔL uniform over
    ``lat_deltas`` and γ uniform over ``gscales`` (degenerate ranges pin
    the value).  Search drivers use this for robust objectives — the same
    seed reproduces the same grid bit-for-bit, so two identical searches
    share result-cache entries.

    ``rng`` is REQUIRED (an int seed or ``numpy.random.Generator``,
    normalized by :func:`repro.core.rng.as_rng`); there is deliberately no
    default and no global-``np.random`` fallback.
    """
    from repro.core.rng import as_rng
    rng = as_rng(rng)
    cls = resolve_class(params, cls)
    n = int(n)
    nc = params.nclass
    dl = rng.uniform(float(lat_deltas[0]), float(lat_deltas[1]), n)
    gs = rng.uniform(float(gscales[0]), float(gscales[1]), n)
    L = np.tile(np.asarray(params.L, dtype=np.float64), (n, 1))
    L[:, cls] = L[:, cls] + dl
    G = np.ones((n, nc))
    G[:, cls] = gs
    return ScenarioBatch(L=L, gscale=G,
                         meta=[{"cls": cls, "dL": float(d), "gscale": float(g)}
                               for d, g in zip(dl, gs)])


# -- resilience: fault & straggler degraded states ----------------------------
#
# Each fault family lowers onto exactly one engine batch axis, so an entire
# fault distribution runs as ONE batched Query (B variants × K cost
# candidates × S scenarios — a single compiled program):
#
#   StragglerFault → K   (per-vertex compute slowdown as a patch_costs row)
#   LinkFault      → S   (per-class ΔL / γ·G as an extra ScenarioBatch row)
#   DeviceFault    → B   (patch_structure variant dropping the failed
#                         rank's message edges) + K (checkpoint-restart
#                         recovery cost on the makespan sinks)


@dataclasses.dataclass(frozen=True)
class StragglerFault:
    """A slow device: the named vertices' compute cost is multiplied by
    ``slowdown``.

    Rides the K (cost-candidate) axis: under max-plus, adding δ to every
    in-edge of v shifts value(v) — and everything downstream of it — by
    exactly δ, so the fault is the zero-recompile ``patch_costs`` row
    ``(slowdown−1)·vcost[v]`` scattered onto v's in-edges.  A vertex with
    no in-edges (a source) cannot be expressed this way and is dropped
    from the row with a warning.
    """

    vertices: tuple                    # vertex ids slowed down together
    slowdown: float                    # ≥ 1: compute-cost multiplier
    name: str = ""

    def __post_init__(self):
        object.__setattr__(self, "vertices",
                           tuple(int(v) for v in np.atleast_1d(self.vertices)))
        if self.slowdown < 1.0:
            raise ValueError(
                f"straggler slowdown must be ≥ 1, got {self.slowdown}")


@dataclasses.dataclass(frozen=True)
class LinkFault:
    """A degraded or flapping link class: +ΔL µs latency and γ× gap on one
    registered network class.  ``duty`` < 1 models flapping — the link is
    degraded that fraction of the time, so the *effective* inflation is
    duty-scaled (ΔL·duty; 1 + (γ−1)·duty).  Rides the S (scenario) axis.
    """

    cls: object                        # class index or registered name
    extra_L_us: float = 0.0
    gscale: float = 1.0
    duty: float = 1.0
    name: str = ""

    def __post_init__(self):
        if not 0.0 < self.duty <= 1.0:
            raise ValueError(f"duty must be in (0, 1], got {self.duty}")
        if self.gscale < 1.0:
            raise ValueError(f"link-fault gscale must be ≥ 1 (slower), "
                             f"got {self.gscale}")


@dataclasses.dataclass(frozen=True)
class DeviceFault:
    """A failed device: message edges incident to ``rank`` are dropped
    (communication with the device ceases for the outage — a
    ``patch_structure`` B variant), and the checkpoint-restart cost of
    bringing it back rides the K axis: ``recovery_us`` added to every
    in-edge of every makespan sink raises T by exactly ``recovery_us``
    (nonnegative costs ⇒ the makespan is attained at a sink).  Model
    ``recovery_us`` from checkpoint accounting via
    :func:`recovery_cost_us`.
    """

    rank: int
    recovery_us: float = 0.0
    name: str = ""

    def __post_init__(self):
        if self.recovery_us < 0.0:
            raise ValueError(
                f"recovery_us must be ≥ 0, got {self.recovery_us}")


def recovery_cost_us(step_us: float, restore_us: float = 0.0,
                     ckpt_every: Optional[int] = None,
                     lost_steps: Optional[float] = None) -> float:
    """Checkpoint-restart recovery cost: restore + lost-work replay (µs).

    ``lost_steps`` is the work discarded by restarting from the last
    committed checkpoint — ``crash_step − CheckpointManager.latest_step()``
    when the failure point is known.  When it isn't, ``ckpt_every`` gives
    the expectation ``(ckpt_every − 1)/2`` for a failure uniform in the
    checkpoint interval.  ``restore_us`` is the measured
    ``CheckpointManager.restore`` wall time.
    """
    if lost_steps is None:
        if ckpt_every is None:
            raise ValueError("recovery_cost_us needs lost_steps or "
                             "ckpt_every (to take the expectation)")
        if ckpt_every < 1:
            raise ValueError(f"ckpt_every must be ≥ 1, got {ckpt_every}")
        lost_steps = (ckpt_every - 1) / 2.0
    if lost_steps < 0:
        raise ValueError(f"lost_steps must be ≥ 0, got {lost_steps}")
    return float(restore_us) + float(lost_steps) * float(step_us)


@dataclasses.dataclass
class FaultAxes:
    """A fault list lowered onto the engine's batch axes (see
    :func:`fault_axes`).  ``structure``/``extras`` are ``None`` when no
    fault rides that axis; ``cells[i]`` is the (b, k, s) cell of fault i
    in the batched result (index 0 on every axis = the intact system)."""

    scenarios: ScenarioBatch
    extras: Optional[np.ndarray]       # [K, ne] patch_costs rows, row 0 = 0
    structure: object                  # StructureBatch (variant 0 intact), or None
    cells: list                        # per-fault (b, k, s)
    names: tuple                       # per-fault labels


def fault_axes(g: ExecutionGraph, params: LogGPS, faults: Sequence,
               plan=None) -> FaultAxes:
    """Lower a fault list onto the engine's B/K/S batch axes.

    Index 0 of every produced axis is the intact system (zero cost row,
    base scenario, unpatched structure), so cell (0, 0, 0) of the batched
    result is the plain forward — the bit-identity anchor — and each
    fault occupies exactly one off-baseline cell (``cells``).  ``plan``
    (a :class:`~repro.sweep.compile.CompiledPlan` of ``g``) is required
    only when device faults are present; it is compiled on demand
    otherwise left untouched.
    """
    faults = list(faults)
    for f in faults:
        if not isinstance(f, (StragglerFault, LinkFault, DeviceFault)):
            raise TypeError(
                f"faults must be StragglerFault / LinkFault / DeviceFault, "
                f"got {type(f).__name__}")
    ne, nv, nc = g.num_edges, g.num_vertices, params.nclass

    # K axis: zero row + one row per straggler + deduped recovery costs
    k_rows: list = [np.zeros(ne)]
    # S axis: base row + one row per link fault
    rows_L = [np.asarray(params.L, dtype=np.float64)]
    rows_G = [np.ones(nc)]
    meta: list = [{"fault": None}]
    # B axis: intact variant + one per device fault
    keeps: list = []

    outdeg = np.bincount(g.esrc, minlength=nv)
    sink_edges = (outdeg == 0)[g.edst]
    recovery_k: dict = {}              # recovery cost → K row index
    cells, names = [], []
    for i, f in enumerate(faults):
        name = f.name or f"{type(f).__name__}[{i}]"
        b = k = s = 0
        if isinstance(f, StragglerFault):
            row = np.zeros(ne)
            for v in f.vertices:
                if not 0 <= v < nv:
                    raise ValueError(
                        f"straggler vertex {v} out of range for {nv}-vertex "
                        f"graph")
                mask = g.edst == v
                if not mask.any():
                    warnings.warn(
                        f"straggler vertex {v} has no in-edges (a source): "
                        "its slowdown cannot ride the cost axis and is "
                        "dropped from the fault row", stacklevel=2)
                    continue
                row[mask] += (f.slowdown - 1.0) * float(g.vcost[v])
            k = len(k_rows)
            k_rows.append(row)
        elif isinstance(f, LinkFault):
            c = resolve_class(params, f.cls)
            L = rows_L[0].copy()
            L[c] += f.extra_L_us * f.duty
            G = np.ones(nc)
            G[c] = 1.0 + (f.gscale - 1.0) * f.duty
            s = len(rows_L)
            rows_L.append(L)
            rows_G.append(G)
            meta.append({"fault": name, "cls": c})
        else:                          # DeviceFault
            drop = (g.ebytes > 0) & ((g.vrank[g.esrc] == f.rank)
                                     | (g.vrank[g.edst] == f.rank))
            if not drop.any():
                warnings.warn(
                    f"device fault on rank {f.rank}: no message edges touch "
                    "that rank — the structural variant equals the intact "
                    "graph", stacklevel=2)
            b = 1 + len(keeps)
            keeps.append(~drop)
            if f.recovery_us > 0.0:
                k = recovery_k.get(f.recovery_us, 0)
                if k == 0:
                    if not sink_edges.any():
                        warnings.warn(
                            "graph has no sink with in-edges: the recovery "
                            "cost cannot ride the cost axis and is dropped",
                            stacklevel=2)
                    else:
                        k = len(k_rows)
                        k_rows.append(np.where(sink_edges, f.recovery_us, 0.0))
                        recovery_k[f.recovery_us] = k
        cells.append((b, k, s))
        names.append(name)

    structure = None
    if keeps:
        if plan is None:
            from .compile import compile_plan
            plan = compile_plan(g, params)
        keep = np.vstack([np.ones(ne, dtype=bool)] + keeps)
        structure = plan.patch_structure(
            keep=keep,
            names=("intact",) + tuple(n for (b, _, _), n in zip(cells, names)
                                      if b > 0))
    extras = np.vstack(k_rows) if len(k_rows) > 1 else None
    scen = ScenarioBatch(L=np.vstack(rows_L), gscale=np.vstack(rows_G),
                         meta=meta)
    return FaultAxes(scenarios=scen, extras=extras, structure=structure,
                     cells=cells, names=tuple(names))


# -- graph-changing axes: stamped variants ------------------------------------

@dataclasses.dataclass
class GraphVariant:
    """A scenario axis that required rebuilding the graph itself."""

    name: str
    graph: ExecutionGraph
    params: LogGPS
    meta: dict = dataclasses.field(default_factory=dict)


def collective_variants(factory: Callable[[str], ExecutionGraph],
                        algos: Sequence[str], params: LogGPS) -> list:
    """Stamp one graph per collective algorithm (the Fig 10 axis).

    ``factory(algo)`` builds the workload with that allreduce/collective
    implementation, e.g. ``lambda a: synth.allreduce_chain(16, 8, algo=a)``.
    """
    return [GraphVariant(name=f"algo={a}", graph=factory(a), params=params,
                         meta={"algo": a}) for a in algos]


def topology_variants(factory: Callable[[topo_mod.Topology, LogGPS],
                                        ExecutionGraph],
                      topos: Sequence[topo_mod.Topology],
                      l_wire_us: float = 0.274,
                      d_switch_us: float = 0.108) -> list:
    """Stamp one wire-class graph per topology (the Fig 11 axis).

    ``factory(topo, params)`` builds the workload with messages expanded via
    :class:`repro.core.topology.TopologyStamper` under ``params`` (whose
    latency classes are the topology's wire classes).
    """
    out = []
    for t in topos:
        p = topo_mod.topology_params(t, l_wire_us=l_wire_us,
                                     d_switch_us=d_switch_us)
        out.append(GraphVariant(name=t.name, graph=factory(t, p), params=p,
                                meta={"topology": t.name}))
    return out


def sweep_variants(variants: Sequence[GraphVariant],
                   batch_of: Callable[[GraphVariant], ScenarioBatch],
                   backend: str = "segment", compute_lam: bool = True,
                   batched: bool = True, max_inflation: float = 64.0,
                   stats: Optional[dict] = None, cache="default") -> dict:
    """DEPRECATED shim over the structure axis — run a variant study
    through :class:`~repro.sweep.api.Engine` directly instead::

        sb = StructureBatch.from_plans(plans, names=names)
        res = Engine(sb).run(Query(scenarios=batch, structure=sb))

    Returns {name: Result} (one :class:`~repro.sweep.api.Result` per
    variant, scenario axis only — attribute-compatible with the legacy
    per-variant ``SweepResult``).

    ``batch_of(variant)`` builds the tensor-batchable sub-grid for that
    variant (base points can differ per variant; latency-class counts can
    differ across topologies).

    With ``batched=True`` (default) variants are grouped into shape buckets
    (:func:`~repro.sweep.compile.group_plans`: same class count, bounded
    padding inflation), each bucket stacks into one
    :class:`~repro.sweep.compile.StructureBatch` riding the engine's B
    axis, and the study costs one compiled call per bucket × distinct
    scenario grid — variants sharing a grid share a call.
    ``batched=False`` restores the per-variant loop (one engine + call per
    graph).

    ``stats``, if given, is filled with {'groups': …, 'calls': …} so callers
    can assert how many compiled dispatches the study cost.

    ``cache``: a :class:`~repro.sweep.cache.SweepCache`, ``None`` to
    disable result memoization (e.g. benchmarks that count compiled
    dispatches), or the default shared cache.
    """
    import warnings
    warnings.warn(
        "sweep_variants() is deprecated: build a StructureBatch "
        "(StructureBatch.from_plans / CompiledPlan.patch_structure) and "
        "run Query(structure=...) on an Engine — same zero-recompile "
        "batching, first-class B axis on the Result",
        DeprecationWarning, stacklevel=2)
    from .api import Engine, ExecPolicy  # avoid cycle
    from .cache import DEFAULT_CACHE
    from .compile import StructureBatch, compile_plan, group_plans

    if cache == "default":
        cache = DEFAULT_CACHE
    policy = ExecPolicy(backend=backend, cache=cache)

    if not batched:
        out = {}
        calls = 0
        for v in variants:
            eng = Engine(v.graph, params=v.params, policy=policy)
            out[v.name] = eng.run(batch_of(v), compute_lam=compute_lam)
            calls += eng.calls
        if stats is not None:
            stats.update(groups=len(variants), calls=calls)
        return out

    plans = [compile_plan(v.graph, v.params) for v in variants]
    groups = group_plans(plans, max_inflation=max_inflation)
    results: dict = {}
    calls = 0
    for idx in groups:
        # the structure axis shares one scenario grid across its B
        # variants, so sub-group the bucket by grid content (one call per
        # distinct grid; identical batch_of outputs — the common case —
        # keep the old one-call-per-bucket count)
        batches = {i: batch_of(variants[i]) for i in idx}
        subs: list = []
        for i in idx:
            key = (batches[i].L.tobytes(), batches[i].gscale.tobytes(),
                   batches[i].L.shape)
            for k2, members in subs:
                if k2 == key:
                    members.append(i)
                    break
            else:
                subs.append((key, [i]))
        for _, members in subs:
            sb = StructureBatch.from_plans(
                [plans[i] for i in members],
                names=[variants[i].name for i in members])
            eng = Engine(sb, policy=policy)
            res = eng.run(batches[members[0]], compute_lam=compute_lam)
            results.update(res.split())
            calls += eng.calls
    if stats is not None:
        stats.update(groups=len(groups), calls=calls)
    return {v.name: results[v.name] for v in variants}
